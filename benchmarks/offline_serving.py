"""Fig. 6: offline serving latency (ms/token) and normalized throughput vs
batch size for CoSine against vLLM-style AR, Vanilla speculation,
SpecInfer-style, and PipeInfer-style baselines."""
from __future__ import annotations

import time


STRATS = ("ar", "vanilla", "specinfer", "pipeinfer", "cosine")


def serve_once(fixture, strategy: str, batch: int, max_new: int = 24,
               prompt_len: int = 16):
    eng = fixture.engine(strategy, max_batch=batch)
    for p, dom in fixture.corpus.prompts(batch, prompt_len, seed=41):
        eng.submit(p, max_new_tokens=max_new, domain=dom)
    st = eng.run()
    # end-to-end latency per generated token, averaged over requests
    lat = [(r.finish_ms - r.arrival_ms) / max(len(r.generated), 1)
           for r in eng.pool.completed]
    return dict(throughput=st.throughput_tps,
                latency_ms_per_token=sum(lat) / max(len(lat), 1),
                acceptance=st.mean_acceptance, sim_ms=st.sim_ms)


def run(fixture, batches=(1, 4, 16), max_new: int = 20):
    rows = []
    for b in batches:
        base = None
        for strat in STRATS:
            t0 = time.time()
            r = serve_once(fixture, strat, b, max_new)
            us = (time.time() - t0) * 1e6
            if strat == "ar":
                base = r
            norm_tput = r["throughput"] / max(base["throughput"], 1e-9)
            lat_vs_ar = (r["latency_ms_per_token"]
                         / max(base["latency_ms_per_token"], 1e-9))
            rows.append((f"fig6_{strat}_b{b}", us,
                         f"ms_per_tok={r['latency_ms_per_token']:.1f};"
                         f"norm_tput={norm_tput:.2f};"
                         f"lat_vs_ar={lat_vs_ar:.2f};"
                         f"acc={r['acceptance']:.2f}"))
    return rows
