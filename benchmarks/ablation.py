"""§6.4 ablation: full CoSine vs w/o cooperative routing vs w/o token
fusion vs SpecInfer, and acceptance improvement vs number of cooperative
drafter nodes."""
from __future__ import annotations

import time

from repro.config import CoSineConfig


def _tput(fixture, strategy, n_drafters=5, enable_routing=True,
          enable_fusion=True, n_prompts=4, max_new=20):
    cos = CoSineConfig(n_drafters=n_drafters, draft_len=5,
                       drafters_per_request=min(2, n_drafters), tree_width=2,
                       enable_routing=enable_routing,
                       enable_fusion=enable_fusion)
    eng = fixture.engine(strategy, cosine=cos, n_drafters=n_drafters)
    for p, dom in fixture.corpus.prompts(n_prompts, 16, seed=71):
        eng.submit(p, max_new_tokens=max_new, domain=dom)
    st = eng.run()
    return st.throughput_tps, st.mean_acceptance


def run(fixture):
    rows = []
    t0 = time.time()
    spec_tps, _ = _tput(fixture, "specinfer")
    variants = {
        "full": dict(),
        "wo_routing": dict(enable_routing=False),
        "wo_fusion": dict(enable_fusion=False),
    }
    for name, kw in variants.items():
        tps, acc = _tput(fixture, "cosine", **kw)
        rows.append((f"ablation_{name}", (time.time() - t0) * 1e6 / 4,
                     f"norm_tput={tps / max(spec_tps, 1e-9):.2f};"
                     f"acc={acc:.2f}"))

    # acceptance vs cooperative node count (Fig. 8 analogue)
    for nd in (1, 2, 3, 5):
        t0 = time.time()
        _, acc = _tput(fixture, "cosine", n_drafters=nd)
        rows.append((f"ablation_nodes_{nd}", (time.time() - t0) * 1e6,
                     f"acc={acc:.2f}"))
    return rows
