"""Shared benchmark fixture: a trained target LLM + five domain-specialized
drafters on the synthetic multi-domain corpus, checkpoint-cached so
repeated benchmark runs skip training.

The corpus is sharp (low-entropy Markov domains) so drafter/target argmax
agreement — and therefore acceptance ratios — lands in the paper's
observed range (Table 2: 1.7-3.2 tokens/iteration)."""
from __future__ import annotations

import os
import time
from dataclasses import dataclass
from typing import List, Tuple


from repro.checkpoint.store import load_checkpoint, save_checkpoint
from repro.config import CoSineConfig, ModelConfig
from repro.configs.drafters import tiny_drafter, tiny_target
from repro.data.synthetic import DOMAINS, SyntheticCorpus

CACHE_DIR = os.path.join(os.path.dirname(__file__), ".bench_cache")
VOCAB = 96
SHARPNESS = 120.0
SUPPORT = 5


@dataclass
class Fixture:
    corpus: SyntheticCorpus
    target: Tuple[ModelConfig, dict]
    drafters: List[Tuple[ModelConfig, dict, str]]
    vocab: int

    def engine(self, strategy: str, cosine: CoSineConfig | None = None,
               n_drafters: int | None = None, seed: int = 0, max_len: int = 512,
               drafters_override=None, drafter_profiles=None, backend=None,
               **cos_kw):
        from repro.serving.engine import SpeculativeEngine
        drafters = (drafters_override if drafters_override is not None
                    else self.drafters[: (n_drafters or len(self.drafters))])
        if cosine is None:
            kw = dict(n_drafters=len(drafters), draft_len=5,
                      drafters_per_request=2, tree_width=2)
            kw.update(cos_kw)
            cosine = CoSineConfig(**kw)
        cos = cosine
        return SpeculativeEngine(self.target, drafters, cos,
                                 strategy=strategy, max_len=max_len, seed=seed,
                                 drafter_profiles=drafter_profiles,
                                 backend=backend)


def build_fixture(steps_target: int = 500, steps_drafter: int = 300,
                  verbose: bool = False, cache_dir: str | None = None) -> Fixture:
    from repro.launch.train import train_model

    corpus = SyntheticCorpus(VOCAB, seed=0, sharpness=SHARPNESS,
                             support=SUPPORT)
    tcfg = tiny_target(VOCAB)
    dcfg = tiny_drafter(VOCAB)

    # non-default training budgets (e.g. the CI quick mode) get their own
    # checkpoint cache so they never poison the fully-trained fixture
    cache_root = cache_dir or (
        CACHE_DIR if (steps_target, steps_drafter) == (500, 300)
        else CACHE_DIR + f"_{steps_target}_{steps_drafter}")
    os.makedirs(cache_root, exist_ok=True)
    tpath = os.path.join(cache_root, "target.msgpack")
    if os.path.exists(tpath):
        tparams, _ = load_checkpoint(tpath)
    else:
        t0 = time.time()
        tparams, losses = train_model(tcfg, corpus, None, steps_target,
                                      batch=16, seq=64, verbose=verbose)
        save_checkpoint(tpath, tparams, {"loss": losses[-1]})
        if verbose:
            print(f"[fixture] target trained in {time.time()-t0:.0f}s "
                  f"loss {losses[0]:.3f}->{losses[-1]:.3f}")

    drafters = []
    for i, dom in enumerate(DOMAINS):
        dpath = os.path.join(cache_root, f"drafter_{dom}.msgpack")
        if os.path.exists(dpath):
            dparams, _ = load_checkpoint(dpath)
        else:
            dparams, losses = train_model(dcfg, corpus, dom, steps_drafter,
                                          batch=16, seq=64, seed=i + 1,
                                          verbose=verbose)
            save_checkpoint(dpath, dparams, {"loss": losses[-1]})
        drafters.append((dcfg, dparams, dom))
    return Fixture(corpus=corpus, target=(tcfg, tparams), drafters=drafters,
                   vocab=VOCAB)


def greedy_reference(tcfg, tparams, prompt, n, max_len=512):
    """The target model's unassisted greedy continuation — the exactness
    oracle every lossless gate compares committed streams against
    (speculation may only change *which* drafts are proposed, never the
    tokens the target commits)."""
    import jax.numpy as jnp
    import numpy as np

    from repro.models import model as M
    cache = M.init_cache(tcfg, 1, max_len, dtype=jnp.float32)
    lg, cache, _ = M.prefill(tparams, tcfg, jnp.asarray(prompt)[None, :],
                             cache)
    last = np.asarray(lg[0, -1, :tcfg.vocab])
    out = []
    for _ in range(n):
        t = int(np.argmax(last))
        out.append(t)
        lg, cache, _ = M.decode_step(tparams, tcfg, jnp.asarray([[t]]), cache)
        last = np.asarray(lg[0, 0, :tcfg.vocab])
    return out


def completion_stats(completed) -> dict:
    """Latency statistics over a list of completed `Request`s, hardened
    against zero-token completions.

    A request that was shed — or preempted and then finished with no
    committed tokens (e.g. max_new_tokens hit exactly at re-admission)
    — has `generated == []` and `first_token_ms == -1`. Those must not
    crash the per-token division or skew the percentiles with bogus
    0-length latencies / negative TTFTs: they simply contribute no
    latency sample (they are accounted separately as shed/goodput loss).
    """
    import numpy as np
    lat = [(r.finish_ms - r.arrival_ms) / len(r.generated)
           for r in completed if r.generated]
    ttft = [r.first_token_ms - r.arrival_ms for r in completed
            if r.generated and r.first_token_ms >= 0.0]

    def pct(q):
        return float(np.percentile(lat, q)) if lat else 0.0

    return dict(
        ms_per_tok=float(np.mean(lat)) if lat else 0.0,
        p50=pct(50), p95=pct(95), p99=pct(99),
        ttft=float(np.mean(ttft)) if ttft else 0.0,
        n_zero_tok=sum(1 for r in completed if not r.generated))


def bench_line(name: str, us_per_call: float, derived: str = "") -> str:
    """The required CSV format: name,us_per_call,derived."""
    return f"{name},{us_per_call:.1f},{derived}"
