"""Heavy-traffic SLO harness (DESIGN.md §2.5): replay arrival traces —
Poisson, 4x-overload bursts, diurnal rate swings — against the pipelined
cosine engine with and without the admission layer, and report the
serving-quality columns the paper's deployment section cares about:

  * p50/p95/p99 per-token latency and mean TTFT (zero-token completions
    — shed, or preempted before first token — contribute no sample
    instead of crashing or skewing the percentiles),
  * goodput_slo: committed tokens from requests that finished *within
    their deadline*, per simulated second — the number admission control
    is supposed to protect under overload,
  * slo_frac: fraction of submitted requests meeting their SLO,
  * accounted: 1.0 iff every submitted request is either completed or
    on the shed list (nothing half-committed or stranded in the pool),
  * lossless (overload rows): 1.0 iff every completed request's tokens
    match the target model's greedy reference — shedding and preemption
    must never corrupt surviving streams.

The adm/noadm row pairs make the tradeoff visible: at low load admission
must cost nothing (goodput_slo >= the noadm row); at 4x overload it
sheds hopeless requests early, so within-SLO goodput degrades gracefully
instead of collapsing with the queue. `accounted`/`lossless` are gated
at zero tolerance in benchmarks/check_regression.
"""
from __future__ import annotations

import math
import time

import numpy as np

from benchmarks.common import completion_stats, greedy_reference

SLO_MS = 6000.0
# the overload rows run a tight SLO (same order as one request's natural
# ~3s service time on this testbed): under a 4x burst that budget is
# genuinely infeasible for the tail, so the shed path engages — with
# admission on, within-SLO goodput and p99 must *improve* over noadm
BURST_SLO_MS = 3000.0
MAX_BATCH = 4
# priority classes cycle 0(high)/1/2(low) so preemption has work to do
PRIORITIES = (1, 0, 1, 1, 2)


def make_trace(mode: str, n: int, seed: int = 0) -> np.ndarray:
    """Arrival timestamps (ms), scaled to the tiny-model testbed where
    the max_batch=4 verifier sustains roughly 5-6 req/s."""
    rng = np.random.default_rng(seed)
    if mode == "poisson_low":          # ~0.5x capacity
        gaps = rng.exponential(350.0, n)
    elif mode == "burst_over4x":       # ~4x capacity, heavily clustered
        gaps = np.array([rng.exponential(220.0) if i % 6 == 0
                         else rng.exponential(8.0) for i in range(n)])
    elif mode == "diurnal":            # rate swings ~0.5x .. ~3x capacity
        t, gaps = 0.0, []
        for _ in range(n):
            rate = (1.75 + 1.25 * math.sin(2 * math.pi * t / 20_000.0)) / 350.0
            g = float(rng.exponential(1.0 / rate))
            gaps.append(g)
            t += g
        gaps = np.array(gaps)
    else:
        raise ValueError(f"unknown trace mode {mode!r}")
    return np.cumsum(gaps)


def serve_trace(fixture, mode: str, admission: bool, n_requests: int = 24,
                max_new: int = 12, slo_ms: float = SLO_MS, seed: int = 11,
                check_lossless: bool = False, lossless_sample: int = 8,
                trace_path=None):
    eng = fixture.engine(
        "cosine", max_batch=MAX_BATCH, enable_admission=admission,
        default_slo_ms=slo_ms, admit_queue_cap=2 * MAX_BATCH)
    arr = make_trace(mode, n_requests, seed=seed)
    for i, ((p, dom), t) in enumerate(
            zip(fixture.corpus.prompts(n_requests, 16, seed=seed + 1), arr)):
        eng.submit(p, max_new_tokens=max_new, domain=dom,
                   arrival_ms=float(t), priority=PRIORITIES[i % 5])
    for _ in range(50_000):
        if eng.step() is None:
            break
    if trace_path:
        # burst replays are the decision-log acceptance target: the
        # sibling .metrics.json carries every λ/γ/admission decision
        from repro.obs.export import export_engine_trace
        export_engine_trace(eng, trace_path)

    comp, shed = eng.pool.completed, eng.pool.shed
    cs = completion_stats(comp)
    ends = [r.finish_ms for r in comp + shed]
    span_s = max((max(ends, default=0.0) - float(arr[0])) / 1e3, 1e-9)
    good_toks = sum(len(r.generated) for r in comp if r.slo_met)
    n_met = sum(1 for r in comp if r.slo_met)
    accounted = float(
        eng.pool.n_submitted == len(comp) + len(shed) and eng.pool.empty
        and all(not r.generated for r in shed))

    out = dict(
        ms_per_tok=cs["ms_per_tok"], p50=cs["p50"], p95=cs["p95"],
        p99=cs["p99"], ttft=cs["ttft"],
        goodput_slo=good_toks / span_s,
        slo_frac=n_met / max(eng.pool.n_submitted, 1),
        n_shed=eng.stats.n_shed, n_preempted=eng.stats.n_preempted,
        accounted=accounted)
    if check_lossless:
        tcfg, tparams = fixture.target
        sample = sorted((r for r in comp if r.generated),
                        key=lambda r: r.rid)[:lossless_sample]
        ok = all(r.generated == greedy_reference(tcfg, tparams, r.prompt,
                                                  len(r.generated))
                 for r in sample)
        out["lossless"] = float(ok)
    return out


def _fmt(m: dict, extra: str = "") -> str:
    s = (f"ms_per_tok={m['ms_per_tok']:.1f};p50={m['p50']:.1f};"
         f"p95={m['p95']:.1f};p99={m['p99']:.1f};ttft_ms={m['ttft']:.0f};"
         f"goodput_slo={m['goodput_slo']:.2f};slo_frac={m['slo_frac']:.3f};"
         f"n_shed={m['n_shed']};n_preempted={m['n_preempted']};"
         f"accounted={m['accounted']:.0f}")
    if "lossless" in m:
        s += f";lossless={m['lossless']:.0f}"
    return s + extra


def run(fixture, quick: bool = False, trace=None):
    n_req = 14 if quick else 24
    max_new = 10 if quick else 12
    grid = [
        ("poisson_low", False), ("poisson_low", True),
        ("burst_over4x", False), ("burst_over4x", True),
        ("diurnal", True),
    ]
    rows, by_name = [], {}
    for mode, adm in grid:
        t0 = time.time()
        burst = mode.startswith("burst")
        tag = "adm" if adm else "noadm"
        m = serve_trace(fixture, mode, adm, n_requests=n_req,
                        max_new=max_new,
                        slo_ms=BURST_SLO_MS if burst else SLO_MS,
                        check_lossless=burst,
                        trace_path=(f"{trace}/traffic_{mode}_{tag}.json"
                                    if trace else None))
        us = (time.time() - t0) * 1e6
        extra = ""
        peer = by_name.get(f"traffic_{mode}_noadm")
        if adm and peer is not None:
            # the acceptance directions: admission is free at low load,
            # and protects within-SLO goodput under 4x overload
            extra = (f";goodput_vs_noadm="
                     f"{m['goodput_slo'] / max(peer['goodput_slo'], 1e-9):.2f}")
        name = f"traffic_{mode}_{tag}"
        by_name[name] = m
        rows.append((name, us, _fmt(m, extra)))
    return rows
