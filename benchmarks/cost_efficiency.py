"""Table 3: cost efficiency (relative $ per token vs an AR-only A100
deployment) across arrival modes, using the paper's Table 1 rental
constants via the LatencyModel cost accounting."""
from __future__ import annotations

import time


from benchmarks.online_serving import make_arrivals


def cost_per_token(fixture, strategy: str, mode: str, n_requests: int = 8,
                   max_new: int = 16):
    eng = fixture.engine(strategy)
    arr = make_arrivals(mode, n_requests, seed=17)
    for (p, dom), t in zip(fixture.corpus.prompts(n_requests, 16, seed=61),
                           arr):
        eng.submit(p, max_new_tokens=max_new, domain=dom, arrival_ms=float(t))
    st = eng.run()
    lat = eng.lat
    # drafter nodes billed by actual participation; server always on
    cost = 0.0
    for rec in st.records:
        cost += rec.t_iter_ms * lat.cost_per_ms(rec.n_active_drafters)
    return cost / max(st.total_committed, 1)


def run(fixture, modes=("low", "high", "volatile")):
    rows = []
    for mode in modes:
        t0 = time.time()
        ar = cost_per_token(fixture, "ar", mode)
        results = {}
        for strat in ("specinfer", "pipeinfer", "cosine"):
            results[strat] = cost_per_token(fixture, strat, mode)
        us = (time.time() - t0) * 1e6
        for strat, c in results.items():
            rows.append((f"table3_{mode}_{strat}", us / 4,
                         f"cost_vs_ar={c / max(ar, 1e-12) * 100:.2f}%"))
    return rows
