"""Fig. 2b: speedup across draft structures — sequential chains of
increasing length, tree-structured drafts, and multi-drafter aggregation.
Speedup = simulated tokens/s normalized to AR decoding."""
from __future__ import annotations

from repro.config import CoSineConfig


def _throughput(fixture, strategy, n_prompts=4, max_new=24, **cos_kw):
    eng = fixture.engine(strategy, **cos_kw)
    for p, dom in fixture.corpus.prompts(n_prompts, 16, seed=11):
        eng.submit(p, max_new_tokens=max_new, domain=dom)
    st = eng.run()
    mean_iter_us = st.sim_ms / max(len(st.records), 1) * 1e3
    return st.throughput_tps, st.mean_acceptance, mean_iter_us


def run(fixture):
    rows = []
    base_tps, _, us = _throughput(fixture, "ar")
    rows.append(("fig2b_ar_baseline", us, "speedup=1.00"))

    for gamma in (2, 4, 8):
        tps, acc, us = _throughput(
            fixture, "vanilla", n_drafters=1,
            cosine=CoSineConfig(n_drafters=1, draft_len=gamma,
                                drafters_per_request=1, tree_width=0))
        rows.append((f"fig2b_sequential_g{gamma}", us,
                     f"speedup={tps / base_tps:.2f};acc={acc:.2f}"))

    for width in (1, 2):
        tps, acc, us = _throughput(
            fixture, "cosine",
            cosine=CoSineConfig(n_drafters=5, draft_len=5,
                                drafters_per_request=2, tree_width=width))
        rows.append((f"fig2b_tree_w{width}", us,
                     f"speedup={tps / base_tps:.2f};acc={acc:.2f}"))

    for nd in (2, 5):
        tps, acc, us = _throughput(
            fixture, "cosine", n_drafters=nd,
            cosine=CoSineConfig(n_drafters=nd, draft_len=5,
                                drafters_per_request=min(2, nd), tree_width=2))
        rows.append((f"fig2b_multidrafter_n{nd}", us,
                     f"speedup={tps / base_tps:.2f};acc={acc:.2f}"))
    return rows
