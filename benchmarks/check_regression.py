"""CI bench-regression gate: compare a fresh benchmark JSON against the
checked-in baseline with per-metric tolerances.

  python -m benchmarks.check_regression \\
      --fresh BENCH_fresh.json --baseline BENCH_online_serving.json

Gated metrics (simulated-deployment numbers, deterministic given the
trained fixture -- wall-clock metrics like us_per_call/wall_us_per_iter
are runner-dependent noise and are reported but never gated):

  * ms_per_tok  -- throughput proxy: fail if it rises more than 15%
  * vutil       -- verifier utilization: fail if it drops more than 15%
  * draft_calls -- drafter token-decodes: fail if it rises more than 15%
                   (sub-batched drafting regressing toward full fan-out)
  * goodput_slo -- within-SLO tokens/s (traffic rows): fail on a >15% drop
  * p99         -- tail latency (traffic rows): fail on a >25% rise
  * accounted / lossless -- zero-tolerance overload invariants: every
                   submitted request completed-or-shed, surviving streams
                   bit-identical to the target's greedy reference
  * traffic_frac / residency_x -- paged-pool rows (``paged`` prefix):
                   decode-view traffic must stay ∝ tokens held and the
                   fixed-memory residency multiple must not drop
  * draft_ratio   -- quantized-drafter serving row (``quant`` prefix):
                   int8-node drafting pace over bf16 must not regress
  * int8_vs_bf16_x / oracle_exact / weight_bytes_x -- int8 GEMV kernel
                   row (``kernel_int8_gemv`` prefix), absolute-gated:
                   the fused path must beat bf16 dense decode, stay
                   bitwise-equal to its oracle, and keep ~2x fewer
                   resident weight bytes

Wall-clock rows (benchmarks/wallclock.py, ``--prefix wallclock``) are
instead gated with ABSOLUTE bounds (ABS_GATES): measured overlap must
stay real (idle_ratio <= ~1, overlap_frac >= 0.5) and the async loop
must keep tracking the simulated clocks (overlap_gap ceiling), while
relative deltas on those noisy measurements are report-only.

A row present in the baseline but missing from the fresh run (or present
but ERROR) fails the gate: lost coverage is a regression too. New rows
(e.g. freshly added sweep columns) are reported and pass.

Exit status: 0 = gate passes, 1 = regression (a readable delta table is
printed either way).
"""

from __future__ import annotations

import argparse
import json
import math
import sys

# metric -> (direction, relative tolerance); direction "up" means larger
# values are worse (gate on increases), "down" means smaller are worse
GATES = {
    "ms_per_tok": ("up", 0.15),
    "vutil": ("down", 0.15),
    # drafter compute: sum over cohorts/nodes of draft_len * |sub-batch|.
    # Route-faithful sub-batching keeps this at ~k*B*gamma per cohort; a
    # >15% rise means drafting regressed toward the N*B full fan-out
    "draft_calls": ("up", 0.15),
    # --- traffic/SLO rows (benchmarks/traffic.py) ---
    # within-SLO committed tokens per simulated second: the quantity
    # admission control protects; a drop means SLO-serving regressed
    "goodput_slo": ("down", 0.15),
    # tail latency under the trace (looser: the tail is the noisiest
    # deterministic metric — a single reordered completion moves it)
    "p99": ("up", 0.25),
    # hard invariants, zero tolerance: every submitted request must be
    # completed-or-shed (never stranded/half-committed), and surviving
    # streams must match the target's greedy reference exactly
    "accounted": ("down", 0.0),
    "lossless": ("down", 0.0),
    # --- paged-pool rows (benchmarks/kernel_bench.bench_paged_pool) ---
    # fraction of reserved per-slot capacity the paged decode view
    # actually streams: the tentpole claim is traffic ∝ tokens held, so
    # a rise means the view is over-covering (e.g. bucket inflation)
    "traffic_frac": ("up", 0.10),
    # requests resident at fixed cache memory vs the reserved layout; a
    # drop means the pool started burning pages it does not need
    "residency_x": ("down", 0.10),
    # --- quantized-drafter rows (DESIGN.md §2.9) ---
    # simulated drafting ms per drafted token, int8 node over bf16 node
    # (quant_serving row): a rise means the mixed pool stopped pricing /
    # exercising the int8 node's faster step
    "draft_ratio": ("up", 0.15),
}
# metric -> (bound, threshold): ABSOLUTE gates for the wall-clock rows
# (benchmarks/wallclock.py), where run-to-run wall noise makes relative
# deltas meaningless but the physical claim is absolute. "max": the
# fresh value must stay <= threshold; "min": must stay >= threshold.
ABS_GATES = {
    # draft-ahead verifier idle over the serial coupled loop's on the
    # perfect-acceptance dispatch-bound row (~0.97 measured, mean over
    # alternating reps). The ceiling catches overlap turning actively
    # harmful; a silently-serialized loop would read ~1.0 and pass, so
    # the structural overlap_frac below is the serialization catcher
    # (the strict <1 demonstration is the slow backend overlap test)
    "idle_ratio": ("max", 1.05),
    # fraction of cohorts that began drafting before the previous
    # verification finished: the structural, noise-immune signature of
    # real concurrency (draft-ahead ~1.0, a serial loop 0.0)
    "overlap_frac": ("min", 0.5),
    # |measured - predicted| accounted verifier utilization, the
    # wall-clock loop vs the discrete-event executor driven by a
    # LatencyModel calibrated from the measured per-cohort durations
    # (~0.06-0.12 measured: the sim does not model host dispatch time,
    # which dilutes the measured utilization on a CPU host)
    "overlap_gap": ("max", 0.25),
    # --- int8 GEMV kernel row (kernel_int8_gemv prefix) ---
    # the fused int8 path must actually beat the bf16 dense matvec at
    # the B-small drafter decode shape (measured ~3.6-4x on this host;
    # the floor only catches it turning into a loss)
    "int8_vs_bf16_x": ("min", 1.05),
    # interpret-mode Pallas kernel vs pure-jnp oracle, bitwise at a
    # tile-aligned shape — correctness, not speed, so absolute
    "oracle_exact": ("min", 1.0),
    # resident weight bytes bf16 over int8+scales (deterministic ~2.0)
    "weight_bytes_x": ("min", 1.5),
}
# reported in the delta table but never gated (noisy or informational)
REPORT_ONLY = (
    "p50",
    "p95",
    "ttft_ms",
    "bubble_ms",
    "invalidated",
    "side",
    "dropped",
    "slo_frac",
    "n_shed",
    "n_preempted",
    # paged-pool rows: wall ratio is host noise; fragmentation and the
    # absolute held-token count are informational
    "paged_vs_slot_x",
    "fragmentation",
    "held_tokens",
)
ROW_FMT = "{:<36} {:<12} {:>10} {:>10} {:>8}  {}"


def parse_derived(derived: str) -> dict:
    """'k=v;k=v' -> {k: float} (non-numeric values are skipped)."""
    out = {}
    for part in derived.split(";"):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        try:
            out[k] = float(v)
        except ValueError:
            continue
    return out


def load_rows(path: str) -> dict:
    with open(path) as f:
        data = json.load(f)
    rows = {}
    for r in data.get("rows", []):
        derived = str(r.get("derived", ""))
        rows[r["name"]] = {"derived": derived, "metrics": parse_derived(derived)}
    return rows


def compare(fresh: dict, base: dict, prefix: str):
    """Returns (table_lines, failure_messages, new_row_names).

    prefix may be comma-separated ("fig7,traffic"): a row is gated when
    its name starts with any of the prefixes."""
    prefixes = tuple(p for p in prefix.split(",") if p)
    failures = []
    lines = [ROW_FMT.format("row", "metric", "base", "fresh", "delta", "verdict")]
    lines.append("-" * len(lines[0]))
    for name, brow in sorted(base.items()):
        if not name.startswith(prefixes):
            continue
        if brow["derived"].startswith("ERROR"):
            # an ERROR baseline row would silently skip every metric:
            # refuse it so a broken artifact can't become the baseline
            failures.append(f"{name}: baseline row is ERROR -- refresh it from a clean run")
            lines.append(ROW_FMT.format(name, "-", "-", "-", "-", "FAIL (bad baseline)"))
            continue
        frow = fresh.get(name)
        if frow is None:
            failures.append(f"{name}: missing from fresh run")
            lines.append(ROW_FMT.format(name, "-", "-", "-", "-", "FAIL (missing)"))
            continue
        if frow["derived"].startswith("ERROR"):
            failures.append(f"{name}: {frow['derived']}")
            lines.append(ROW_FMT.format(name, "-", "-", "-", "-", "FAIL (error)"))
            continue
        metrics = (list(GATES)
                   + [m for m in ABS_GATES if m not in GATES]
                   + list(REPORT_ONLY))
        for metric in metrics:
            bv = brow["metrics"].get(metric)
            fv = frow["metrics"].get(metric)
            if (metric in GATES or metric in ABS_GATES) \
                    and bv is not None and fv is None:
                # the baseline gates this metric but the fresh run no
                # longer reports it -- silently skipping would disable
                # the gate (lost coverage is a regression)
                failures.append(f"{name}.{metric}: missing from fresh row")
                row = ROW_FMT.format(name, metric, f"{bv:.3f}", "-", "-", "FAIL (missing)")
                lines.append(row)
                continue
            if bv is None or fv is None:
                continue
            if bv:
                delta = (fv - bv) / bv
            else:
                # a zero baseline must not disable the gate: any move off
                # zero is an unbounded relative change (e.g. draft_calls
                # appearing on a strategy that never drafted)
                delta = 0.0 if fv == bv else math.copysign(math.inf, fv - bv)
            verdict = "ok"
            if metric in GATES:
                direction, tol = GATES[metric]
                bad = delta > tol if direction == "up" else delta < -tol
                if bad:
                    verdict = f"FAIL (>{tol:.0%})"
                    msg = f"{bv:.3f} -> {fv:.3f} ({delta:+.1%}, tolerance {tol:.0%})"
                    failures.append(f"{name}.{metric}: {msg}")
            if metric in ABS_GATES and verdict == "ok":
                bound, thr = ABS_GATES[metric]
                bad = fv > thr if bound == "max" else fv < thr
                if bad:
                    op = "<=" if bound == "max" else ">="
                    verdict = f"FAIL (abs {op} {thr:g})"
                    failures.append(f"{name}.{metric}: {fv:.3f} violates absolute bound {op} {thr:g}")
            row = ROW_FMT.format(name, metric, f"{bv:.3f}", f"{fv:.3f}", f"{delta:+.1%}", verdict)
            lines.append(row)
    new_rows = sorted(n for n in fresh if n not in base and n.startswith(prefixes))
    return lines, failures, new_rows


# observability gate (DESIGN.md §2.6): the trace's accounted verify-track
# busy/idle totals must reproduce the benchmark's vutil column. Any drift
# beyond float/µs-rounding noise means the span accounting and the
# ServeStats accounting have diverged — an accounting bug, not noise.
TRACE_VUTIL_TOL = 0.001


def trace_vutil(path: str):
    """(vutil, busy_ms, idle_ms) of the verify stage track, recomputed
    from the exported trace alone: non-bubble spans are busy, ``bubble``
    spans are idle; projected per-request copies (args.stage) and other
    tracks are excluded."""
    with open(path) as f:
        trace = json.load(f)
    busy = idle = 0.0
    for ev in trace["traceEvents"]:
        args = ev.get("args", {})
        if (
            ev.get("ph") != "X"
            or ev.get("cat") != "stage"
            or args.get("track") != "verify"
            or "stage" in args
        ):
            continue
        if ev.get("name") == "bubble":
            idle += ev.get("dur", 0.0)
        else:
            busy += ev.get("dur", 0.0)
    return busy / max(busy + idle, 1e-9), busy / 1e3, idle / 1e3


def check_trace(path: str, fresh: dict, row_name: str):
    """Gate one exported trace against the fresh run's vutil column."""
    frow = fresh.get(row_name)
    if frow is None or "vutil" not in frow["metrics"]:
        return [f"trace gate: fresh row {row_name!r} has no vutil metric"]
    bench_v = frow["metrics"]["vutil"]
    tv, busy_ms, idle_ms = trace_vutil(path)
    drift = abs(tv - bench_v) / max(bench_v, 1e-9)
    print(
        f"\ntrace gate: {path} verify busy={busy_ms:.2f}ms idle={idle_ms:.2f}ms "
        f"vutil={tv:.5f} vs {row_name} vutil={bench_v:.5f} (drift {drift:.5%})"
    )
    if drift > TRACE_VUTIL_TOL:
        return [
            f"trace {path}: accounted vutil {tv:.5f} drifts {drift:.3%} from "
            f"{row_name} vutil {bench_v:.5f} (tolerance {TRACE_VUTIL_TOL:.1%}) "
            f"-- span accounting and ServeStats have diverged"
        ]
    return []


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", required=True, help="benchmark JSON from this run")
    ap.add_argument("--baseline", required=True, help="checked-in baseline JSON")
    ap.add_argument(
        "--prefix",
        default="fig7,traffic,paged,quant,kernel_int8_gemv",
        help="comma-separated name prefixes to gate (kernel wall-times are noise)",
    )
    ap.add_argument(
        "--trace",
        default=None,
        metavar="PATH",
        help="exported trace JSON: gate its accounted verify busy/idle "
        "totals against the fresh run's vutil",
    )
    ap.add_argument(
        "--trace-row",
        default="fig7_high_cosine",
        help="fresh row whose vutil the trace must reproduce",
    )
    args = ap.parse_args(argv)

    fresh = load_rows(args.fresh)
    base = load_rows(args.baseline)
    lines, failures, new_rows = compare(fresh, base, args.prefix)
    if args.trace:
        failures.extend(check_trace(args.trace, fresh, args.trace_row))
    print("\n".join(lines))
    if new_rows:
        print(f"\nnew rows (not in baseline, not gated): {', '.join(new_rows)}")
    if failures:
        print(f"\nBENCH REGRESSION GATE FAILED ({len(failures)} violation(s)):", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("\nbench regression gate passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
