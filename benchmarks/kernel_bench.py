"""Kernel microbenchmarks: interpret-mode Pallas vs pure-jnp oracle wall
time (CPU: correctness-bearing only — TPU timing comes from the roofline),
plus the XLA blocked-attention path used by the serving models."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.ssd_scan.ops import ssd
from repro.kernels.ssd_scan.ref import ssd_reference
from repro.kernels.tree_attention.ops import tree_attention
from repro.kernels.tree_attention.ref import tree_attention_ref


def _time(fn, *args, iters=3, **kw):
    fn(*args, **kw)  # warmup/compile
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def run(fixture=None):
    rows = []
    B, H, R, S, Msz, D = 2, 4, 16, 512, 16, 64
    ks = [jax.random.normal(jax.random.PRNGKey(i), s) for i, s in enumerate([
        (B, H, R, D), (B, H, S, D), (B, H, S, D), (B, H, Msz, D),
        (B, H, Msz, D)])]
    q, kc, vc, kseg, vseg = ks
    cp = jnp.broadcast_to(jnp.arange(S), (B, S)).astype(jnp.int32)
    qp = jnp.full((B, R), S, jnp.int32)
    mask = jnp.tril(jnp.ones((R, Msz), bool))[None].repeat(B, 0)

    us_k = _time(tree_attention, q, kc, vc, cp, kseg, vseg, qp, mask,
                 scale=0.125, interpret=True)
    us_r = _time(tree_attention_ref, q, kc, vc, cp, kseg, vseg, qp, mask,
                 scale=0.125)
    rows.append(("kernel_tree_attention_interp", us_k, f"ref_us={us_r:.0f}"))

    G = 8
    q2 = jax.random.normal(jax.random.PRNGKey(9), (B, H, G, D))
    qp2 = jnp.full((B,), S - 1, jnp.int32)
    us_k = _time(decode_attention, q2, kc, vc, cp, qp2, scale=0.125,
                 interpret=True)
    us_r = _time(decode_attention_ref, q2, kc, vc, cp, qp2, scale=0.125)
    rows.append(("kernel_decode_attention_interp", us_k, f"ref_us={us_r:.0f}"))

    b, L, Hs, P, G_, N = 1, 256, 8, 32, 1, 32
    kk = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(kk[0], (b, L, Hs, P))
    dt = jax.nn.softplus(jax.random.normal(kk[1], (b, L, Hs)))
    A = -jnp.exp(jax.random.normal(kk[2], (Hs,)))
    Bm = jax.random.normal(kk[3], (b, L, G_, N))
    Cm = jax.random.normal(kk[4], (b, L, G_, N))
    us_k = _time(ssd, x, dt, A, Bm, Cm, chunk=64, interpret=True)
    us_r = _time(ssd_reference, x, dt, A, Bm, Cm)
    rows.append(("kernel_ssd_scan_interp", us_k, f"ref_us={us_r:.0f}"))
    return rows
