"""Kernel microbenchmarks: interpret-mode Pallas vs pure-jnp oracle wall
time (CPU: correctness-bearing only — TPU timing comes from the roofline),
plus the XLA blocked-attention path used by the serving models and the
slot-based serving-cache engine vs the legacy per-request stack/split
flow."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.decode_attention.ops import (decode_attention,
                                                decode_attention_paged)
from repro.kernels.decode_attention.ref import (decode_attention_paged_ref,
                                                decode_attention_ref)
from repro.kernels.int8_gemv.ops import int8_gemv, int8_gemv_xla
from repro.kernels.int8_gemv.ref import int8_gemv_ref
from repro.kernels.ssd_scan.ops import ssd
from repro.kernels.ssd_scan.ref import ssd_reference
from repro.kernels.tree_attention.ops import tree_attention
from repro.kernels.tree_attention.ref import tree_attention_ref


def _time(fn, *args, iters=3, **kw):
    fn(*args, **kw)  # warmup/compile
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def run(fixture=None, quick=False):
    rows = []
    B, H, R, S, Msz, D = 2, 4, 16, 512, 16, 64
    ks = [jax.random.normal(jax.random.PRNGKey(i), s) for i, s in enumerate([
        (B, H, R, D), (B, H, S, D), (B, H, S, D), (B, H, Msz, D),
        (B, H, Msz, D)])]
    q, kc, vc, kseg, vseg = ks
    cp = jnp.broadcast_to(jnp.arange(S), (B, S)).astype(jnp.int32)
    qp = jnp.full((B, R), S, jnp.int32)
    mask = jnp.tril(jnp.ones((R, Msz), bool))[None].repeat(B, 0)

    us_k = _time(tree_attention, q, kc, vc, cp, kseg, vseg, qp, mask,
                 scale=0.125, interpret=True)
    us_r = _time(tree_attention_ref, q, kc, vc, cp, kseg, vseg, qp, mask,
                 scale=0.125)
    rows.append(("kernel_tree_attention_interp", us_k, f"ref_us={us_r:.0f}"))

    G = 8
    q2 = jax.random.normal(jax.random.PRNGKey(9), (B, H, G, D))
    qp2 = jnp.full((B,), S - 1, jnp.int32)
    us_k = _time(decode_attention, q2, kc, vc, cp, qp2, scale=0.125,
                 interpret=True)
    us_r = _time(decode_attention_ref, q2, kc, vc, cp, qp2, scale=0.125)
    rows.append(("kernel_decode_attention_interp", us_k, f"ref_us={us_r:.0f}"))

    b, L, Hs, P, G_, N = 1, 256, 8, 32, 1, 32
    kk = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(kk[0], (b, L, Hs, P))
    dt = jax.nn.softplus(jax.random.normal(kk[1], (b, L, Hs)))
    A = -jnp.exp(jax.random.normal(kk[2], (Hs,)))
    Bm = jax.random.normal(kk[3], (b, L, G_, N))
    Cm = jax.random.normal(kk[4], (b, L, G_, N))
    us_k = _time(ssd, x, dt, A, Bm, Cm, chunk=64, interpret=True)
    us_r = _time(ssd_reference, x, dt, A, Bm, Cm)
    rows.append(("kernel_ssd_scan_interp", us_k, f"ref_us={us_r:.0f}"))

    # paged decode kernel: block-table walk vs gather-then-dense oracle
    ps, npg, nv = 64, 18, 8
    kp = jax.random.normal(jax.random.PRNGKey(11), (npg, H, ps, D))
    vp = jax.random.normal(jax.random.PRNGKey(12), (npg, H, ps, D))
    ppos = jnp.where(jnp.arange(npg)[:, None] >= 2,
                     (jnp.arange(npg)[:, None] - 2) * ps
                     + jnp.arange(ps)[None], -1).astype(jnp.int32)
    tbl = (2 + jnp.arange(B * nv, dtype=jnp.int32)).reshape(B, nv)
    qp3 = jnp.full((B,), nv * ps - 1, jnp.int32)
    us_k = _time(decode_attention_paged, q2, kp, vp, ppos, qp3, tbl,
                 scale=0.125, interpret=True)
    us_r = _time(decode_attention_paged_ref, q2, kp, vp, ppos, qp3, tbl,
                 scale=0.125)
    rows.append(("kernel_decode_paged_interp", us_k, f"ref_us={us_r:.0f}"))

    rows.extend(bench_int8_gemv(quick=quick))
    rows.extend(bench_slot_cache())
    rows.extend(bench_write_path(quick=quick))
    rows.extend(bench_paged_pool(quick=quick))
    return rows


def bench_int8_gemv(B: int = 1, K: int = 1024, N: int = 4096,
                    iters: int = 30, quick: bool = False):
    """Weight-only int8 GEMV at the drafter decode hot shape (DESIGN.md
    §2.9): one activation row against a (K, N) dense weight, the
    B-small regime where the step is bound on streaming the weight.

    One gated row, three claims:

      int8_vs_bf16_x — wall speedup of the K-blocked int8 GEMV
          (`int8_gemv_xla`: int8 weights resident, dequant per block in
          cache) over the bf16 dense matvec the unquantized drafter
          runs. Absolute-gated (>= ~1.05): the int8 path must actually
          beat bf16 at drafter shapes, with margin measured ~3.6x at
          B=1 on this host.
      oracle_exact — interpret-mode Pallas kernel vs the pure-jnp
          oracle, bitwise at a tile-aligned shape (the kernel tiles N
          only, one full-K dot per tile — same reduction order as the
          oracle). Zero-tolerance gate.
      weight_bytes_x — resident weight bytes, bf16 over int8+scales
          (deterministic ~2x; the roofline quantity the speedup cashes
          in).
    """
    if quick:
        iters = 10
    kx, kw = jax.random.split(jax.random.PRNGKey(5))
    x = jax.random.normal(kx, (B, K), jnp.float32)
    w = (jax.random.normal(kw, (K, N), jnp.float32) / np.sqrt(K))
    absmax = jnp.max(jnp.abs(w), axis=0, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    w8 = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
    w_bf16 = w.astype(jnp.bfloat16)

    bf16_dot = jax.jit(lambda a, b: (a.astype(jnp.bfloat16) @ b)
                       .astype(jnp.float32))
    us_bf16 = _time(bf16_dot, x, w_bf16, iters=iters)
    us_int8 = _time(int8_gemv_xla, x, w8, scale.reshape(1, -1), iters=iters)

    # bitwise oracle check at a tile-aligned shape (interpret Pallas)
    Ba, Ka, Na = 8, 256, 384
    xa = jax.random.normal(jax.random.PRNGKey(7), (Ba, Ka), jnp.float32)
    w8a = jax.random.randint(jax.random.PRNGKey(8), (Ka, Na), -127, 128,
                             jnp.int8)
    sa = jnp.full((1, Na), 0.01, jnp.float32)
    got = int8_gemv(xa, w8a, sa, interpret=True)
    want = int8_gemv_ref(xa, w8a, sa)
    exact = float(np.array_equal(np.asarray(got), np.asarray(want)))

    bytes_bf16 = w.size * 2
    bytes_int8 = w8.size * 1 + scale.size * 4
    return [(f"kernel_int8_gemv_b{B}_k{K}_n{N}", us_int8,
             f"bf16_us={us_bf16:.0f};"
             f"int8_vs_bf16_x={us_bf16 / max(us_int8, 1e-9):.2f};"
             f"oracle_exact={exact:.0f};"
             f"weight_bytes_x={bytes_bf16 / bytes_int8:.3f}")]


def bench_slot_cache(B: int = 8, iters: int = 30):
    """Per-iteration host overhead of the serving cache flows at batch B.

    Three decode loops with identical device compute:
      base  — jitted decode on one already-batched cache (lower bound:
              pure compute + dispatch, no cache management at all)
      stack — legacy per-request flow: stack B batch-1 pytrees, decode,
              split back (what ModelRunner did before the slot engine)
      slot  — slot-resident decode through ModelRunner (gather/scatter
              inside the jitted step)
    Host overhead is the loop time above `base`; the slot engine must
    eliminate (>=2x reduce) the stack/split overhead.

    Shapes are chosen small (shallow model, short capacity) so the
    measurement isolates HOST dispatch/pytree cost; `bench_write_path`
    covers the bandwidth-bound regime (deep model, long capacity) where
    the in-place slot-indexed write path must beat the old gather/scatter
    composition on device-side byte traffic.
    """
    from repro.config import ModelConfig
    from repro.models import model as M
    from repro.serving.runner import ModelRunner

    cfg = ModelConfig(name="bench-slot", family="dense", n_layers=4,
                      d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
                      d_ff=128, vocab=128, tie_embeddings=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    max_len = 256
    rng = np.random.default_rng(0)
    prompt_len = 16

    jit_decode = jax.jit(M.decode_step, static_argnames=("cfg",))
    tok_b = jnp.zeros((B, 1), jnp.int32)

    # --- setup OUTSIDE the timed regions: only decode iterations are timed
    base_cache = M.init_cache(cfg, B, max_len, dtype=jnp.float32)
    _, base_cache, _ = M.prefill(
        params, cfg, jnp.asarray(rng.integers(0, cfg.vocab, (B, prompt_len)),
                                 jnp.int32), base_cache)

    stack_caches = []
    for _ in range(B):
        c = M.init_cache(cfg, 1, max_len, dtype=jnp.float32)
        _, c, _ = M.prefill(
            params, cfg, jnp.asarray(rng.integers(0, cfg.vocab,
                                                  (1, prompt_len)),
                                     jnp.int32), c)
        stack_caches.append(c)

    runner = ModelRunner(cfg, params, max_len=max_len, n_slots=B)
    rids = list(range(B))
    for r in rids:
        runner.prefill_request(r, rng.integers(0, cfg.vocab, prompt_len))
    tok_np = np.zeros((B,), np.int32)

    def loop_base():
        nonlocal base_cache
        lg = None
        for _ in range(iters):
            lg, base_cache, _ = jit_decode(params, cfg=cfg, tokens=tok_b,
                                           cache=base_cache)
        jax.block_until_ready(lg)

    def loop_stack():
        nonlocal stack_caches
        lg = None
        for _ in range(iters):
            stacked = M.stack_caches(stack_caches)
            lg, stacked, _ = jit_decode(params, cfg=cfg, tokens=tok_b,
                                        cache=stacked)
            stack_caches = M.split_cache(stacked, B)
        jax.block_until_ready(lg)

    def loop_slot():
        for _ in range(iters):
            runner.decode(rids, tok_np)
        jax.block_until_ready(runner.slots.cache["lengths"])

    def timed(fn):
        fn()                       # warmup/compile
        t0 = time.time()
        fn()
        return (time.time() - t0) / iters * 1e6

    us_base = timed(loop_base)
    us_stack = timed(loop_stack)
    us_slot = timed(loop_slot)
    # host overhead above the pure compute+dispatch floor; the slot path can
    # land below the floor (donation updates in place), so clamp at 0 and
    # headline the direct per-iteration speedup instead of an overhead ratio
    ovh_stack = max(us_stack - us_base, 0.0)
    ovh_slot = max(us_slot - us_base, 0.0)
    return [(f"serving_slot_decode_b{B}", us_slot,
             f"stack_us={us_stack:.0f};base_us={us_base:.0f};"
             f"host_ovh_stack_us={ovh_stack:.0f};"
             f"host_ovh_slot_us={ovh_slot:.0f};"
             f"stack_vs_slot_x={us_stack / max(us_slot, 1e-9):.1f}")]


def bench_write_path(B: int = 8, max_len: int = 2048, n_slots: int = 16,
                     iters: int = 20, quick: bool = False):
    """In-place slot-indexed cache writes vs the legacy gather/scatter
    round trip, at a bandwidth-bound shape (deep model, long max_len).

    Both flows run the same jitted decode compute; the difference is
    cache byte traffic per step:

      scatter — gather_slots (bucket x capacity copy) -> decode_step ->
                scatter_slots (bucket x capacity write-back): the PR-1
                composition, per-step bytes scale with pool capacity.
      inplace — apply(..., slot_idx=...): new KV rows scattered directly
                into the donated resident cache; reads gather only the
                active rows. Per-step written bytes scale with the number
                of new tokens (paged-attention style).

    The in-place path must win at this shape — that is the acceptance
    criterion for the resident write path (ISSUE 3); at tiny shapes both
    are host-dispatch-bound and converge."""
    from functools import partial

    from repro.config import ModelConfig
    from repro.models import model as M
    from repro.serving.runner import ModelRunner

    if quick:
        iters = 8
    cfg = ModelConfig(name="bench-write", family="dense", n_layers=8,
                      d_model=128, n_heads=8, n_kv_heads=4, head_dim=32,
                      d_ff=256, vocab=128, tie_embeddings=True,
                      dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)

    runner = ModelRunner(cfg, params, max_len=max_len, n_slots=n_slots)
    rids = list(range(B))
    for r in rids:
        runner.prefill_request(r, rng.integers(0, cfg.vocab, 64))
    idx = runner.slots.padded_idx(rids)
    tok = jnp.zeros((int(idx.shape[0]), 1), jnp.int32)

    jit_inplace = jax.jit(M.slot_decode_step, static_argnames=("cfg",),
                          donate_argnames=("cache",))

    def scatter_step(params, tokens, cache, slot_idx, *, cfg):
        sub = M.gather_slots(cache, slot_idx)
        lg, sub, aux = M.decode_step(params, cfg, tokens, sub)
        return lg, M.scatter_slots(cache, sub, slot_idx), aux

    jit_scatter = jax.jit(partial(scatter_step, cfg=cfg),
                          donate_argnames=("cache",))

    def loop(step):
        cache = jax.tree.map(jnp.copy, runner.slots.cache)
        lg, cache, _ = step(params, tokens=tok, cache=cache, slot_idx=idx)
        jax.block_until_ready(lg)          # warmup/compile
        t0 = time.time()
        for _ in range(iters):
            lg, cache, _ = step(params, tokens=tok, cache=cache,
                                slot_idx=idx)
        jax.block_until_ready(lg)
        return (time.time() - t0) / iters * 1e6

    us_in = loop(lambda params, **kw: jit_inplace(params, cfg=cfg, **kw))
    us_sc = loop(jit_scatter)
    return [(f"serving_write_path_b{B}_len{max_len}", us_in,
             f"gather_scatter_us={us_sc:.0f};"
             f"inplace_vs_scatter_x={us_sc / max(us_in, 1e-9):.2f}")]


def bench_paged_pool(B: int = 8, max_len: int = 2048, n_slots: int = 16,
                     page_size: int = 64, prompt_len: int = 64,
                     iters: int = 20, quick: bool = False):
    """Paged pool (DESIGN.md §2.8) vs reserved-capacity slot cache at the
    bandwidth-bound shape of `bench_write_path`.

    Two rows, both gated against the checked-in baseline:

      paged_decode_* — decode traffic ∝ tokens HELD: `traffic_frac` is
          the fraction of the reserved per-slot capacity the paged view
          actually streams per step (n_view pages / capacity); the
          resident path always reads the full capacity (frac 1.0). Also
          checks `lossless` (paged decode logits bitwise equal to the
          resident path, zero tolerance) and reports the wall ratio
          (`paged_vs_slot_x`, host-noise — report-only).

      paged_residency_* — requests resident at FIXED cache memory:
          the resident cache burns max_len rows per slot regardless of
          occupancy; the pool burns only each request's mapped pages.
          `residency_x` = how many more requests of this length fit in
          the same token-row footprint (>= 1.0; gated against drops).
    """
    from repro.config import ModelConfig
    from repro.models import model as M
    from repro.serving.runner import ModelRunner

    if quick:
        iters = 8
    cfg = ModelConfig(name="bench-paged", family="dense", n_layers=8,
                      d_model=128, n_heads=8, n_kv_heads=4, head_dim=32,
                      d_ff=256, vocab=128, tie_embeddings=True,
                      dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, prompt_len) for _ in range(B)]

    res = ModelRunner(cfg, params, max_len=max_len, n_slots=n_slots)
    pag = ModelRunner(cfg, params, max_len=max_len, n_slots=n_slots,
                      paged=True, page_size=page_size)
    rids = list(range(B))
    for r in rids:
        res.prefill_request(r, prompts[r])
        pag.prefill_request(r, prompts[r])
    tok = np.zeros((B,), np.int32)

    def loop(runner):
        lg = None
        for _ in range(iters):
            lg, _ = runner.decode(rids, tok)
        jax.block_until_ready(runner.slots.cache["lengths"])
        return lg

    def timed(runner):
        loop(runner)                   # warmup/compile
        t0 = time.time()
        lg = loop(runner)
        return (time.time() - t0) / iters * 1e6, lg

    us_res, lg_res = timed(res)
    us_pag, lg_pag = timed(pag)
    lossless = float(np.array_equal(np.asarray(lg_res), np.asarray(lg_pag)))

    # decode-read traffic: columns the next step's view streams per
    # request, as a fraction of the reserved per-slot capacity
    view_cols = int(pag.slots.prepare(rids, write=0).shape[1]) * page_size
    traffic_frac = view_cols / max_len

    # residency at fixed memory: token rows one request pins
    held = max(pag.slots.pages_held() // B, 1) * page_size
    residency_x = max_len / held
    frag = pag.slots.fragmentation()

    return [
        (f"paged_decode_b{B}_len{max_len}", us_pag,
         f"slot_us={us_res:.0f};paged_vs_slot_x={us_res / max(us_pag, 1e-9):.2f};"
         f"traffic_frac={traffic_frac:.4f};lossless={lossless:.0f}"),
        (f"paged_residency_len{max_len}", 0.0,
         f"held_tokens={held};residency_x={residency_x:.2f};"
         f"fragmentation={frag:.4f}"),
    ]
