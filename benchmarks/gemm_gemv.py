"""Fig. 2a: GEMM vs GEMV operation balance in speculative drafting vs
parallel verification.

For each phase we classify every matmul in the model's step by its
effective M dimension (rows of activations hitting a weight matrix):
M == 1 per sequence -> GEMV-class (memory-bound weight streaming);
M > 1 -> GEMM-class (compute-bound). FLOP shares are computed analytically
from the model dims; wall time per phase is measured on CPU for the
derived column. This reproduces the paper's observation that sequential
drafting is GEMV-dominated while batched verification is GEMM-dominated.
"""
from __future__ import annotations

import time

import numpy as np

from repro.config import ModelConfig


def matmul_flop_split(cfg: ModelConfig, tokens_per_forward: int):
    """Returns (gemv_flops, gemm_flops) for one forward of the model with
    `tokens_per_forward` activation rows per weight matrix."""
    d, hq, hkv, hd, f = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                         cfg.resolved_head_dim, cfg.d_ff)
    per_token = 2 * (d * hq * hd + 2 * d * hkv * hd + hq * hd * d
                     + 3 * d * f) * cfg.n_layers + 2 * d * cfg.vocab
    total = per_token * tokens_per_forward
    if tokens_per_forward == 1:
        return total, 0.0
    return 0.0, total


def run(fixture, n_iters: int = 20):
    tcfg, tparams = fixture.target
    dcfg = fixture.drafters[0][0]
    gamma = 5

    # drafting = gamma sequential single-token forwards of the SSM
    gemv_d, gemm_d = matmul_flop_split(dcfg, 1)
    gemv_d *= gamma
    # verification = one forward over gamma tokens of the LLM
    gemv_v, gemm_v = matmul_flop_split(tcfg, gamma)

    eng = fixture.engine("vanilla", n_drafters=1)
    p, dom = fixture.corpus.prompts(1, 16, seed=0)[0]
    eng.submit(p, max_new_tokens=4, domain=dom)
    eng.run()  # warm up jits

    d0 = fixture.drafters[0]
    from repro.serving.runner import ModelRunner
    drafter = ModelRunner(dcfg, d0[1], 128)
    target = ModelRunner(tcfg, tparams, 128)
    ctx = fixture.corpus.sample("piqa", 32)
    drafter.prefill_request(0, ctx)
    target.prefill_request(0, ctx)

    t0 = time.time()
    for _ in range(n_iters):
        tok = np.array([1], np.int32)
        for _ in range(gamma):
            lg, _ = drafter.decode([0], tok)
            tok = np.argmax(lg, -1).astype(np.int32)
    t_draft = (time.time() - t0) / n_iters * 1e6

    toks = np.tile(ctx[:gamma][None], (1, 1)).astype(np.int32)
    rel = np.arange(gamma, dtype=np.int32)[None]
    mask = np.tril(np.ones((gamma, gamma), bool))[None]
    t0 = time.time()
    for _ in range(n_iters):
        target.verify([0], toks, rel, mask)
    t_verify = (time.time() - t0) / n_iters * 1e6

    # int8 drafter: the GEMV phase is weight-streaming-bound, so
    # weight-only int8 (models/quantize.py) halves its roofline bytes.
    # Same decode loop on the quantized drafter, plus the analytic
    # byte split that feeds analysis/analytic.py's weight-stream term.
    import jax
    from repro.analysis.analytic import weight_stream_bytes
    from repro.models.quantize import quantize_params
    qcfg = dcfg.with_overrides(quant="int8")
    qdrafter = ModelRunner(qcfg, quantize_params(d0[1]), 128)
    qdrafter.prefill_request(0, ctx)
    t0 = time.time()
    for _ in range(n_iters):
        tok = np.array([1], np.int32)
        for _ in range(gamma):
            lg, _ = qdrafter.decode([0], tok)
            tok = np.argmax(lg, -1).astype(np.int32)
    t_draft_q = (time.time() - t0) / n_iters * 1e6

    n_params = float(sum(np.prod(l.shape)
                         for l in jax.tree.leaves(d0[1])))
    wb_bf16 = weight_stream_bytes(dcfg, n_params)
    wb_int8 = weight_stream_bytes(qcfg, n_params)

    rows = []
    tot_d = gemv_d + gemm_d
    tot_v = gemv_v + gemm_v
    rows.append(("fig2a_draft_gemv_share", t_draft,
                 f"gemv_frac={gemv_d / tot_d:.3f}"))
    rows.append(("fig2a_verify_gemm_share", t_verify,
                 f"gemm_frac={gemm_v / tot_v:.3f}"))
    rows.append(("fig2a_us_per_drafted_token", t_draft / gamma, ""))
    rows.append(("fig2a_us_per_verified_token", t_verify / gamma, ""))
    rows.append(("fig2a_us_per_drafted_token_int8", t_draft_q / gamma,
                 f"bf16_us={t_draft / gamma:.1f}"))
    rows.append(("fig2a_draft_weight_bytes_x", wb_bf16 / wb_int8,
                 f"bf16_B={wb_bf16:.3g} int8_B={wb_int8:.3g}"))
    return rows
