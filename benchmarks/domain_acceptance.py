"""Table 2 / Fig. 3a: acceptance ratio of each domain-specialized drafter on
each domain (the diagonal should dominate — measured, not assumed).

Calibration note: with the one-behind drafter caches (drafting off-by-one
fixed) drafter chains condition on exactly the context the target
verifies, so per-domain acceptance sits slightly higher than the
historical numbers; the paper-range check (Table 2: ~1.7-3.2
tokens/iteration on the sharp synthetic corpus) still holds and the
diagonal-dominance ratio is unaffected in direction."""
from __future__ import annotations

import time

import numpy as np

from repro.config import CoSineConfig
from repro.data.synthetic import DOMAINS


def acceptance_matrix(fixture, n_prompts=2, max_new=24):
    mat = {}
    for di, (dcfg, dparams, ddom) in enumerate(fixture.drafters):
        for dom in DOMAINS:
            eng = fixture.engine(
                "vanilla",
                cosine=CoSineConfig(n_drafters=1, draft_len=5,
                                    drafters_per_request=1, tree_width=0),
                drafters_override=[(dcfg, dparams, ddom)])
            prompts = [pd for pd in fixture.corpus.prompts(5 * n_prompts, 16,
                                                           seed=21)
                       if pd[1] == dom][:n_prompts]
            for p, d in prompts:
                eng.submit(p, max_new_tokens=max_new, domain=d)
            st = eng.run()
            iters = sum(r.n_iterations for r in eng.pool.completed)
            mat[(ddom, dom)] = st.total_committed / max(iters, 1)
    return mat


def run(fixture):
    t0 = time.time()
    mat = acceptance_matrix(fixture)
    us = (time.time() - t0) * 1e6
    rows = []
    for (drafter, dom), acc in sorted(mat.items()):
        rows.append((f"table2_acc_{drafter}_on_{dom}", us / len(mat),
                     f"acc={acc:.2f}"))
    diag = np.mean([mat[(d, d)] for d in DOMAINS])
    off = np.mean([v for (dr, dm), v in mat.items() if dr != dm])
    rows.append(("table2_diag_vs_offdiag", us / len(mat),
                 f"in_domain={diag:.2f};cross_domain={off:.2f};"
                 f"ratio={diag / max(off, 1e-9):.2f}"))
    return rows
