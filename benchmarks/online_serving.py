"""Fig. 7: online serving latency under low / high / volatile Poisson
request arrival rates, CoSine vs baselines — plus the heterogeneous
drafter-cluster straggler sweep (DESIGN.md §2.4).

Besides the latency/TTFT columns, each row reports the pipeline-health
numbers measured by the discrete-event executor (DESIGN.md §2.2):
verifier utilization (busy over busy+bubble), total bubble ms,
draft-ahead invalidation count, and — for pipelined strategies — the
per-drafter-node utilizations measured off each node's stage clock.
Route-faithful drafting compute shows up as `draft_calls` (total drafter
token-decodes, ~= k*B*gamma per cohort instead of the SpecInfer-style
N*B*gamma) and `dtoks` (the per-node drafted-token split — each node's
routed sub-batch sizes times the draft length).

The straggler sweep runs cosine on a cluster where one node is slowed by
a factor (2x, 4x): the cut-loose policy keeps the verifier fed, so
cosine's bubble time should stay below the homogeneous-cluster pipeinfer
baseline row even with the slow node.

`run(fixture, quick=True)` is the CI smoke mode (fewer requests, high +
volatile arrivals, 2x sweep only) used to produce the
BENCH_online_serving.json artifact gated by benchmarks/check_regression.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import completion_stats
from repro.core.latency_model import DrafterProfile


def make_arrivals(mode: str, n: int, seed: int = 0):
    """Arrival timestamps (ms). Rates scaled to the tiny-model testbed."""
    rng = np.random.default_rng(seed)
    if mode == "low":
        gaps = rng.exponential(400.0, n)
    elif mode == "high":
        gaps = rng.exponential(120.0, n)
    else:  # volatile: alternating bursts and lulls
        gaps = np.concatenate([
            rng.exponential(60.0, n // 2), rng.exponential(500.0, n - n // 2)])
        rng.shuffle(gaps)
    return np.cumsum(gaps)


def serve_online(fixture, strategy: str, mode: str, n_requests: int = 10,
                 max_new: int = 16, profiles=None, trace_path=None,
                 drafters_override=None, return_engine=False):
    eng = fixture.engine(strategy, drafter_profiles=profiles,
                         drafters_override=drafters_override)
    arr = make_arrivals(mode, n_requests, seed=7)
    for (p, dom), t in zip(fixture.corpus.prompts(n_requests, 16, seed=51),
                           arr):
        eng.submit(p, max_new_tokens=max_new, domain=dom, arrival_ms=float(t))
    # step the engine ourselves, timing each iteration: the median is the
    # steady-state host cost per iteration (robust to first-call / new-shape
    # XLA compiles, which would swamp a total-time / n_iters average)
    iter_wall_s = []
    for _ in range(10_000):
        t0 = time.perf_counter()
        if eng.step() is None:
            break
        iter_wall_s.append(time.perf_counter() - t0)
    if trace_path:
        from repro.obs.export import export_engine_trace
        export_engine_trace(eng, trace_path)
    cstats = completion_stats(eng.pool.completed)
    stats = eng.stats
    if return_engine:
        return eng, cstats
    dutil = dlate = ""
    n_side = n_dropped = 0
    if eng.executor is not None:
        cl = eng.executor.cluster
        dutil = "|".join(f"{f:.2f}" for f in cl.busy_fracs())
        dlate = "|".join(str(c) for c in cl.node_late)
        n_side, n_dropped = cl.n_side, cl.n_dropped
    # route-faithful drafting compute: draft_calls = sum over cohorts and
    # nodes of draft_len * |routed sub-batch| (~= k*B*gamma per cohort,
    # vs the SpecInfer-style N*B*gamma full fan-out); dtoks is the
    # per-node split of the same count
    return dict(
        ms_per_tok=cstats["ms_per_tok"],
        p95=cstats["p95"],
        ttft=cstats["ttft"],
        wall_iter_us=float(np.median(iter_wall_s)) * 1e6 if iter_wall_s
        else 0.0,
        vutil=float(stats.verifier_utilization),
        bubble_ms=float(stats.verifier_idle_ms),
        n_invalid=int(stats.n_invalidated),
        draft_calls=int(stats.draft_calls),
        dtoks="|".join(str(c) for c in stats.node_drafted),
        dutil=dutil, dlate=dlate, n_side=n_side, n_dropped=n_dropped)


def _fmt(m, extra=""):
    # wall_us_per_iter: median real host time per engine iteration — the
    # slot-cache engine's steady-state dispatch cost (the ms_per_tok
    # numbers are simulated deployment time); vutil/bubble_ms/invalidated
    # are measured off the executor's event timeline (analytic
    # decomposition for coupled baselines); dutil is the per-drafter-node
    # utilization vector, cut/side the straggler-policy outcomes
    s = (f"ms_per_tok={m['ms_per_tok']:.1f};p95={m['p95']:.1f};"
         f"ttft_ms={m['ttft']:.0f};"
         f"wall_us_per_iter={m['wall_iter_us']:.0f};"
         f"vutil={m['vutil']:.3f};bubble_ms={m['bubble_ms']:.0f};"
         f"invalidated={m['n_invalid']};draft_calls={m['draft_calls']}")
    if m["dtoks"]:
        s += f";dtoks={m['dtoks']}"
    if m["dutil"]:
        s += (f";dutil={m['dutil']};dlate={m['dlate']};side={m['n_side']};"
              f"dropped={m['n_dropped']}")
    return s + extra


def _hetero_profiles(n: int, slow_factor: float, slow_node: int = 0):
    """Homogeneous cluster with one node slowed by `slow_factor`."""
    return tuple(DrafterProfile(speed=slow_factor if i == slow_node else 1.0)
                 for i in range(n))


def run(fixture, strategies=("ar", "specinfer", "pipeinfer", "cosine"),
        modes=("low", "high", "volatile"), quick: bool = False,
        trace=None):
    if quick:
        modes = ("high", "volatile")
    n_req = 6 if quick else 10
    max_new = 12 if quick else 16
    rows = []
    base = base_us = None   # homogeneous pipeinfer @ high: the straggler-
    #                         sweep baseline (reused from the mode grid)
    for mode in modes:
        ref = None
        for strat in strategies:
            t0 = time.time()
            m = serve_online(
                fixture, strat, mode, n_requests=n_req, max_new=max_new,
                trace_path=(f"{trace}/fig7_{mode}_{strat}.json"
                            if trace else None))
            us = (time.time() - t0) * 1e6
            if strat == "specinfer":
                ref = m["ms_per_tok"]
            if strat == "pipeinfer" and mode == "high":
                base, base_us = m, us
            extra = ""
            if strat == "cosine" and ref:
                extra = (f";x_vs_specinfer="
                         f"{ref / max(m['ms_per_tok'], 1e-9):.2f}")
            rows.append((f"fig7_{mode}_{strat}", us, _fmt(m, extra)))

    # --- heterogeneity / straggler sweep (one slowed node, high rate) ---
    n_nodes = len(fixture.drafters)
    sweep = (2.0,) if quick else (2.0, 4.0)
    if base is None:  # high mode wasn't in the grid: run the baseline
        t0 = time.time()
        base = serve_online(fixture, "pipeinfer", "high", n_requests=n_req,
                            max_new=max_new)
        base_us = (time.time() - t0) * 1e6
    rows.append(("fig7_hetero_pipeinfer_homog", base_us, _fmt(base)))
    for f in sweep:
        t0 = time.time()
        m = serve_online(fixture, "cosine", "high", n_requests=n_req,
                         max_new=max_new,
                         profiles=_hetero_profiles(n_nodes, f),
                         trace_path=(f"{trace}/fig7_hetero_slow{f:g}x"
                                     f"_cosine.json" if trace else None))
        us = (time.time() - t0) * 1e6
        # the acceptance direction: straggler cut-off keeps cosine's
        # verifier bubble below the homogeneous pipeinfer baseline
        extra = (f";bubble_vs_pipeinfer="
                 f"{m['bubble_ms'] / max(base['bubble_ms'], 1e-9):.2f}")
        rows.append((f"fig7_hetero_slow{f:g}x_cosine", us, _fmt(m, extra)))

    rows.extend(quant_rows(fixture, n_req=n_req, max_new=max_new))
    return rows


def quant_rows(fixture, n_req: int = 6, max_new: int = 12):
    """Mixed-precision pool row (DESIGN.md §2.9): drafter 0 runs
    weight-only int8 beside the bf16 rest, under cosine routing/fusion.

    Gated claims:
      lossless    — committed streams bitwise equal the target's greedy
                    reference (zero tolerance: quantization only changes
                    *drafts*, never what the target commits).
      draft_ratio — simulated drafting ms per drafted token on the int8
                    node over a bf16 node: the engine's default pool
                    profiles must keep pricing the int8 node at
                    INT8_DRAFT_SPEED (~0.6), and the routed load must
                    actually exercise it.
    """
    from benchmarks.common import greedy_reference
    d = list(fixture.drafters)
    override = [(d[0][0].with_overrides(quant="int8"), d[0][1], d[0][2])] \
        + d[1:]
    t0 = time.time()
    eng, cstats = serve_online(fixture, "cosine", "high", n_requests=n_req,
                               max_new=max_new, drafters_override=override,
                               return_engine=True)
    us = (time.time() - t0) * 1e6

    tcfg, tparams = fixture.target
    comp = sorted((r for r in eng.pool.completed if r.generated),
                  key=lambda r: r.rid)
    ok = all(r.generated == greedy_reference(tcfg, tparams, r.prompt,
                                             len(r.generated))
             for r in comp)

    # per-node simulated drafting pace: busy ms on the node's stage clock
    # over the token-decodes it executed (routed sub-batches x draft len)
    nodes = eng.executor.cluster.nodes
    dtoks = eng.stats.node_drafted
    pace = [n.busy_ms / t if t else 0.0 for n, t in zip(nodes, dtoks)]
    bf16_pace = [p for p, c in zip(pace[1:], override[1:]) if p > 0]
    ratio = (pace[0] / (sum(bf16_pace) / len(bf16_pace))
             if pace[0] > 0 and bf16_pace else 0.0)

    speeds = "|".join(f"{p.speed:g}" for p in eng.drafter_profiles)
    return [("quant_serving_int8_pool", us,
             f"ms_per_tok={cstats['ms_per_tok']:.1f};"
             f"lossless={float(ok):.0f};draft_ratio={ratio:.3f};"
             f"node_speeds={speeds};"
             f"dtoks={'|'.join(str(c) for c in dtoks)}")]
