"""Fig. 7: online serving latency under low / high / volatile Poisson
request arrival rates, CoSine vs baselines.

Besides the latency/TTFT columns, each row reports the pipeline-health
numbers measured by the discrete-event executor (DESIGN.md §2.2):
verifier utilization (busy over busy+bubble), total bubble ms, and
draft-ahead invalidation count. For the coupled baselines the bubble is
the full draft+comm phase every iteration, so the pipelined strategies'
measured utilization exceeding them is the paper's overlap made
*emergent* rather than assumed.

`run(fixture, quick=True)` is the CI smoke mode (fewer requests, high +
volatile arrivals only) used to produce the BENCH_online_serving.json
artifact."""
from __future__ import annotations

import time

import numpy as np


def make_arrivals(mode: str, n: int, seed: int = 0):
    """Arrival timestamps (ms). Rates scaled to the tiny-model testbed."""
    rng = np.random.default_rng(seed)
    if mode == "low":
        gaps = rng.exponential(400.0, n)
    elif mode == "high":
        gaps = rng.exponential(120.0, n)
    else:  # volatile: alternating bursts and lulls
        gaps = np.concatenate([
            rng.exponential(60.0, n // 2), rng.exponential(500.0, n - n // 2)])
        rng.shuffle(gaps)
    return np.cumsum(gaps)


def serve_online(fixture, strategy: str, mode: str, n_requests: int = 10,
                 max_new: int = 16):
    eng = fixture.engine(strategy)
    arr = make_arrivals(mode, n_requests, seed=7)
    for (p, dom), t in zip(fixture.corpus.prompts(n_requests, 16, seed=51),
                           arr):
        eng.submit(p, max_new_tokens=max_new, domain=dom, arrival_ms=float(t))
    # step the engine ourselves, timing each iteration: the median is the
    # steady-state host cost per iteration (robust to first-call / new-shape
    # XLA compiles, which would swamp a total-time / n_iters average)
    iter_wall_s = []
    for _ in range(10_000):
        t0 = time.perf_counter()
        if eng.step() is None:
            break
        iter_wall_s.append(time.perf_counter() - t0)
    lat = [(r.finish_ms - r.arrival_ms) / max(len(r.generated), 1)
           for r in eng.pool.completed]
    ttft = [r.first_token_ms - r.arrival_ms for r in eng.pool.completed]
    stats = eng.stats
    return (float(np.mean(lat)), float(np.percentile(lat, 95)),
            float(np.mean(ttft)),
            float(np.median(iter_wall_s)) * 1e6 if iter_wall_s else 0.0,
            float(stats.verifier_utilization),
            float(stats.verifier_idle_ms),
            int(stats.n_invalidated))


def run(fixture, strategies=("ar", "specinfer", "pipeinfer", "cosine"),
        modes=("low", "high", "volatile"), quick: bool = False):
    if quick:
        modes = ("high", "volatile")
    rows = []
    for mode in modes:
        ref = None
        for strat in strategies:
            t0 = time.time()
            (mean_lat, p95, ttft, wall_iter_us, vutil, bubble_ms,
             n_invalid) = serve_online(
                fixture, strat, mode,
                n_requests=6 if quick else 10,
                max_new=12 if quick else 16)
            us = (time.time() - t0) * 1e6
            if strat == "specinfer":
                ref = mean_lat
            extra = ""
            if strat == "cosine" and ref:
                extra = f";x_vs_specinfer={ref / max(mean_lat, 1e-9):.2f}"
            # wall_us_per_iter: median real host time per engine iteration —
            # the slot-cache engine's steady-state dispatch cost (the
            # ms_per_tok numbers above are simulated deployment time);
            # vutil/bubble_ms/invalidated are measured off the executor's
            # event timeline (analytic decomposition for coupled baselines)
            rows.append((f"fig7_{mode}_{strat}", us,
                         f"ms_per_tok={mean_lat:.1f};p95={p95:.1f};"
                         f"ttft_ms={ttft:.0f};"
                         f"wall_us_per_iter={wall_iter_us:.0f};"
                         f"vutil={vutil:.3f};bubble_ms={bubble_ms:.0f};"
                         f"invalidated={n_invalid}{extra}"))
    return rows
