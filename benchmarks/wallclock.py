"""Wall-clock validation of the async serving loop against the
discrete-event clocks (DESIGN.md §2.7): run the real `AsyncJaxBackend`
and compare *measured* verifier overlap with what the simulated model
predicts, on the same trained fixture.

Two rows, each gating what it can honestly gate
(`check_regression.py --prefix wallclock` vs BENCH_wallclock.json):

  * **wallclock_pipelined** — loop mechanics in isolation: small
    dispatch-bound models (one op must not saturate the host, else
    concurrent drafting only contends) with the target serving as its
    own drafter (acceptance ~= 1, so every draft-ahead survives).
    Gates:
      - `overlap_frac` (absolute floor): fraction of cohorts whose
        drafting began before the previous verification finished —
        structural evidence the draft/verify concurrency is physical
        (a serial loop measures 0.0), immune to wall noise. This is
        the silent-serialization catcher: a broken overlap would read
        idle_ratio ~= 1.0 and still pass that ceiling;
      - `idle_ratio` (absolute ceiling): measured verifier idle
        fraction of the draft-ahead loop over the serial coupled
        loop's on the identical workload, mean over alternating reps;
        ~0.97 measured here (the strict < 1 demonstration lives in
        tests/test_backend.py::test_async_overlap_beats_serial_idle),
        the ceiling catches overlap turning actively harmful.
  * **wallclock_serving** — the trained-drafter cosine deployment.
    Gates:
      - `lossless` (zero tolerance): async committed streams are
        greedy-exact against the target reference;
      - `overlap_gap` (absolute ceiling): |measured − predicted|
        accounted verifier utilization (§2.2 busy/(busy+idle), the
        same `vutil` the sim rows gate), where the prediction is the
        simulated engine on the same workload driven by a LatencyModel
        least-squares-fitted to this run's measured per-cohort
        draft/verify durations (comm_ms=0 on one host).
    Its `idle_ratio_real` is REPORTED, not gated: with this fixture's
    ~2-3 tokens/chain acceptance, draft-ahead survival is ~10%, so the
    overlapped loop redrafts most cohorts and its idle is not below
    the serial loop's — the measured physics of speculation on a
    shared host, worth tracking, wrong to gate.

Wall-clock numbers are noisy (CI shares cores), so the gated metrics
are either structural (overlap_frac) or absolute with generous
margins; raw `us_per_call` is informational.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.config import CoSineConfig
from repro.core.latency_model import LatencyModel


def _greedy_reference(tcfg, tparams, prompt, n, max_len=512):
    from repro.models import model as M
    cache = M.init_cache(tcfg, 1, max_len, dtype=jnp.float32)
    lg, cache, _ = M.prefill(tparams, tcfg, jnp.asarray(prompt)[None, :],
                             cache)
    last = np.asarray(lg[0, -1, :tcfg.vocab])
    out = []
    for _ in range(n):
        t = int(np.argmax(last))
        out.append(t)
        lg, cache, _ = M.decode_step(tparams, tcfg, jnp.asarray([[t]]), cache)
        last = np.asarray(lg[0, 0, :tcfg.vocab])
    return out


def _mechanics_models(vocab):
    """Dispatch-bound models for the loop-mechanics row: small enough
    that one op does not saturate the host's cores, so drafting in
    parallel with an in-flight verification is physically free capacity
    rather than contention. (With the fixture's d_model=256 target a
    single forward already occupies every core and concurrent drafting
    only contends — measured, see DESIGN.md §2.7.) Random init is fine:
    the target drafts for itself, so acceptance is perfect regardless
    of training."""
    import jax

    from repro.config import ModelConfig
    from repro.models import model as M
    cfg = ModelConfig(name="wallclock-mech", family="dense", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
                      d_ff=128, vocab=vocab, tie_embeddings=True,
                      dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _serve_async(fixture, strategy, n_requests, max_new, dl,
                 drafters_override=None, force_serial=False,
                 target=None, max_len=512):
    """One wall-clock run, burst arrival (the overlap question is about
    steady-state pipelining, not arrival lulls)."""
    if target is None:
        eng = fixture.engine(strategy, backend="async", draft_len=dl,
                             drafters_override=drafters_override)
    else:
        from repro.serving.engine import SpeculativeEngine
        cos = CoSineConfig(n_drafters=len(drafters_override), draft_len=dl,
                           drafters_per_request=2, tree_width=2)
        eng = SpeculativeEngine(target, drafters_override, cos,
                                strategy=strategy, max_len=max_len, seed=0,
                                backend="async")
    if force_serial:
        eng.executor.overlap = False
    for (p, dom) in fixture.corpus.prompts(n_requests, 16, seed=29):
        eng.submit(p, max_new_tokens=max_new, domain=dom, arrival_ms=0.0)
    iter_wall_s = []
    for _ in range(10_000):
        t0 = time.perf_counter()
        if eng.step() is None:
            break
        iter_wall_s.append(time.perf_counter() - t0)
    eng.backend.shutdown()
    wall_us = float(np.median(iter_wall_s)) * 1e6 if iter_wall_s else 0.0
    return eng, eng.stats, wall_us


def _idle_frac(stats) -> float:
    busy, idle = stats.verifier_busy_ms, stats.verifier_idle_ms
    return idle / max(busy + idle, 1e-9)


def _overlap_frac(stats) -> float:
    """Fraction of cohort transitions where the next cohort's drafting
    started before the previous verification finished — the structural
    signature of draft/verify concurrency (serial loop: 0.0)."""
    rs = stats.records
    if len(rs) < 2:
        return 0.0
    hits = sum(
        1 for prev, nxt in zip(rs, rs[1:])
        if nxt.draft_start_ms < prev.verify_start_ms + prev.verify_ms)
    return hits / (len(rs) - 1)


def _busy_frac(stats) -> float:
    """Accounted verifier utilization (§2.2: busy over busy+idle) — the
    same ServeStats quantity the sim rows already gate as `vutil`,
    computed identically for the measured and the simulated run."""
    busy, idle = stats.verifier_busy_ms, stats.verifier_idle_ms
    return busy / max(busy + idle, 1e-9)


def _fit_latency_from(stats, ctx_len: float) -> LatencyModel:
    """LatencyModel calibrated to this machine from the measured
    per-cohort wall durations (host dispatch overhead included — that
    IS the machine being modeled). Single host: comm_ms=0."""
    lat = LatencyModel()
    lat.comm_ms = 0.0
    llm, ssm = [], []
    for r in stats.records:
        if r.verify_ms > 0:
            llm.append((r.batch, ctx_len, r.big_gamma, r.verify_ms))
        if r.draft_ms > 0 and r.batch > 0:
            # per-request chain depth ~ tree nodes per request (exact
            # for chain trees; a mild overcount with side branches)
            ssm.append((r.batch, ctx_len, max(r.big_gamma // r.batch, 1),
                        r.draft_ms))
    if len(llm) >= 3:
        lat.fit_llm(llm)
    if len(ssm) >= 3:
        lat.fit_ssm(ssm)
    return lat


def _predict_busy_frac(fixture, lat, n_requests, max_new, dl) -> float:
    """The discrete-event prediction: the simulated engine on the same
    workload, with the measured-calibrated LatencyModel."""
    eng = fixture.engine("cosine", draft_len=dl)
    eng.lat = lat
    eng.executor.cluster.lat = lat
    for (p, dom) in fixture.corpus.prompts(n_requests, 16, seed=29):
        eng.submit(p, max_new_tokens=max_new, domain=dom, arrival_ms=0.0)
    eng.run()
    return _busy_frac(eng.stats)


def run(fixture, quick: bool = False):
    n_requests = 4 if quick else 8
    max_new = 16 if quick else 24

    rows = []

    # ---- row 1: pipelined loop mechanics, perfect acceptance --------
    # dispatch-bound models, and the target drafts for itself: every
    # speculation survives, so the measurement isolates the loop
    # discipline from drafter quality and from host-core contention
    mcfg, mparams = _mechanics_models(fixture.vocab)
    perfect = [(mcfg, mparams, d) for d in ("alpaca", "fiqa")]
    common = dict(n_requests=8, max_new=32, dl=8,
                  drafters_override=perfect, target=(mcfg, mparams),
                  max_len=128)
    # warm the jit caches with the exact measured shapes (compiles
    # would otherwise inflate the first run's spans and bias the ratio)
    _serve_async(fixture, "vanilla", **common)
    _serve_async(fixture, "pipeinfer", **common)
    # alternate measured reps so slow host drift cancels out of the
    # ratio; the mean over reps is what the absolute gate sees
    reps_serial, reps_over = [], []
    wall_us = 0.0
    for _ in range(2 if quick else 3):
        _, s_serial, _ = _serve_async(fixture, "vanilla", **common)
        _, s_over, wall_us = _serve_async(fixture, "pipeinfer", **common)
        reps_serial.append(_idle_frac(s_serial))
        reps_over.append(_idle_frac(s_over))
    idle_serial = float(np.mean(reps_serial))
    idle_over = float(np.mean(reps_over))
    idle_ratio = idle_over / max(idle_serial, 1e-9)
    rows.append(("wallclock_pipelined", wall_us,
                 f"idle_ratio={idle_ratio:.3f};"
                 f"overlap_frac={_overlap_frac(s_over):.3f};"
                 f"idle_serial={idle_serial:.3f};"
                 f"idle_overlap={idle_over:.3f}"))

    # ---- row 2: realistic serving, trained drafters -----------------
    common = dict(n_requests=n_requests, max_new=max_new, dl=5)
    _serve_async(fixture, "cosine", **common)                  # warm
    _serve_async(fixture, "cosine", force_serial=True, **common)
    eng, s_cos, wall_us = _serve_async(fixture, "cosine", **common)
    _, s_cos_ser, _ = _serve_async(fixture, "cosine", force_serial=True,
                                   **common)

    tcfg, tparams = fixture.target
    comp = eng.pool.completed
    lossless = float(
        len(comp) == n_requests
        and all(list(map(int, r.generated)) == _greedy_reference(
            tcfg, tparams, r.prompt, len(r.generated))
            for r in comp))

    ctx_len = 16 + max_new / 2.0
    lat = _fit_latency_from(s_cos, ctx_len)
    pred = _predict_busy_frac(fixture, lat, n_requests, max_new, 5)
    meas = _busy_frac(s_cos)
    gap = abs(meas - pred)

    rows.append((
        "wallclock_serving", wall_us,
        f"lossless={lossless:.0f};overlap_gap={gap:.3f};"
        f"overlap_frac={_overlap_frac(s_cos):.3f};"
        f"vutil_measured={meas:.3f};vutil_predicted={pred:.3f};"
        f"idle_ratio_real="
        f"{_idle_frac(s_cos) / max(_idle_frac(s_cos_ser), 1e-9):.3f};"
        f"invalidated={s_cos.n_invalidated}"))
    return rows
