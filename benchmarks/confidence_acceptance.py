"""Fig. 3b: draft-token acceptance ratio vs drafter confidence percentile —
the empirical basis for confidence-based token fusion (high-confidence
tokens are accepted far more often)."""
from __future__ import annotations

import time

import numpy as np

from repro.config import CoSineConfig


def collect_confidence_acceptance(fixture, n_prompts: int = 6,
                                  max_new: int = 32):
    """Instrument a vanilla engine: for every drafted chain token record
    (drafter confidence, accepted?). Returns (N, 2) array.

    Note: drafter chains condition on the exact committed context (the
    one-behind drafter caches fixed the historical duplicated-token
    off-by-one), so acceptance rates here are the calibrated reference
    for the fusion threshold analysis — expect them a notch above the
    pre-fix numbers at every confidence percentile."""
    eng = fixture.engine("vanilla", n_drafters=1,
                         cosine=CoSineConfig(n_drafters=1, draft_len=5,
                                             drafters_per_request=1,
                                             tree_width=0))
    conf_acc = []
    state = {}
    orig_draft = eng._draft_entries
    orig_fin = eng._finalize

    def draft_probe(batch, gammas, optimistic=None):
        entries = orig_draft(batch, gammas, optimistic)
        state.update({e.req.rid: e for e in entries})
        return entries

    def finalize_probe(batch, committed, rec):
        for r in batch:
            e = state[r.rid]
            n_acc = max(len(committed[r.rid]) - 1, 0)  # last = correction
            for i in range(e.tree.chain_len):
                conf_acc.append((float(e.d_confs[0, i]), i < n_acc))
        return orig_fin(batch, committed, rec)

    eng._draft_entries = draft_probe
    eng._finalize = finalize_probe
    for p, dom in fixture.corpus.prompts(n_prompts, 16, seed=31):
        eng.submit(p, max_new_tokens=max_new, domain=dom)
    eng.run()
    return np.array(conf_acc, dtype=float)


def run(fixture):
    t0 = time.time()
    arr = collect_confidence_acceptance(fixture)
    us = (time.time() - t0) * 1e6
    rows = []
    qs = np.quantile(arr[:, 0], [0.0, 0.25, 0.5, 0.75, 0.9, 1.0])
    names = ["q0_25", "q25_50", "q50_75", "q75_90", "q90_100"]
    for lo, hi, name in zip(qs[:-1], qs[1:], names):
        sel = (arr[:, 0] >= lo) & (arr[:, 0] <= hi)
        acc = arr[sel, 1].mean() if sel.any() else float("nan")
        rows.append((f"fig3b_accept_{name}", us / len(names),
                     f"conf=[{lo:.2f},{hi:.2f}];accept_rate={acc:.3f}"))
    top = arr[arr[:, 0] >= qs[-2], 1].mean()
    rest = arr[arr[:, 0] < qs[-2], 1].mean()
    rows.append(("fig3b_top10pct_vs_rest", us / len(names),
                 f"top={top:.3f};rest={rest:.3f};"
                 f"uplift={(top / max(rest, 1e-9) - 1) * 100:.0f}%"))
    return rows
