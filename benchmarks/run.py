"""Benchmark harness (deliverable d): one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --only fig6,table2
  PYTHONPATH=src python -m benchmarks.run --only fig7 --quick \
      --json BENCH_online_serving.json               # CI smoke artifact
"""
from __future__ import annotations

import argparse
import inspect
import json
import sys
import time
import traceback

BENCHES = [
    ("fig2a_gemm_gemv", "benchmarks.gemm_gemv", True),
    ("fig2b_draft_structures", "benchmarks.draft_structures", True),
    ("table2_domain_acceptance", "benchmarks.domain_acceptance", True),
    ("fig3b_confidence", "benchmarks.confidence_acceptance", True),
    ("fig6_offline_serving", "benchmarks.offline_serving", True),
    ("fig7_online_serving", "benchmarks.online_serving", True),
    ("wallclock", "benchmarks.wallclock", True),
    ("traffic_slo", "benchmarks.traffic", True),
    ("table3_cost_efficiency", "benchmarks.cost_efficiency", True),
    ("ablation", "benchmarks.ablation", True),
    ("kernels", "benchmarks.kernel_bench", False),
    ("roofline", "benchmarks.roofline", False),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", type=str, default=None,
                    help="comma-separated substring filters")
    ap.add_argument("--skip-fixture", action="store_true",
                    help="run only benches that need no trained models")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke mode: lightly-trained fixture, reduced "
                         "workloads for benches that support quick=")
    ap.add_argument("--json", type=str, default=None, metavar="PATH",
                    help="also write the rows as a JSON artifact")
    ap.add_argument("--trace", type=str, nargs="?", const="traces",
                    default=None, metavar="DIR",
                    help="export per-run Perfetto traces + metrics JSON "
                         "into DIR (default ./traces) for benches that "
                         "support it (fig7, traffic)")
    args = ap.parse_args()
    if args.trace:
        import os
        os.makedirs(args.trace, exist_ok=True)
    only = args.only.split(",") if args.only else None

    selected = [(n, m, f) for n, m, f in BENCHES
                if only is None or any(o in n for o in only)]
    needs_fixture = any(f for _, _, f in selected) and not args.skip_fixture

    fixture = None
    if needs_fixture:
        from benchmarks.common import build_fixture
        t0 = time.time()
        print(f"# building/loading benchmark fixture...", file=sys.stderr)
        if args.quick:
            fixture = build_fixture(steps_target=160, steps_drafter=100,
                                    verbose=True)
        else:
            fixture = build_fixture(verbose=True)
        print(f"# fixture ready in {time.time() - t0:.0f}s", file=sys.stderr)

    print("name,us_per_call,derived")
    failures = 0
    all_rows = []
    for name, modname, needs_fx in selected:
        if needs_fx and fixture is None:
            continue
        try:
            mod = __import__(modname, fromlist=["run"])
            kw = {}
            params = inspect.signature(mod.run).parameters
            if args.quick and "quick" in params:
                kw["quick"] = True
            if args.trace and "trace" in params:
                kw["trace"] = args.trace
            rows = mod.run(fixture, **kw) if needs_fx else mod.run(**kw)
            for r in rows:
                print(f"{r[0]},{r[1]:.1f},{r[2]}")
                all_rows.append({"name": r[0], "us_per_call": float(r[1]),
                                 "derived": r[2]})
            sys.stdout.flush()
        except Exception as e:
            failures += 1
            print(f"{name},0.0,ERROR:{type(e).__name__}:{e}")
            all_rows.append({"name": name, "us_per_call": 0.0,
                             "derived": f"ERROR:{type(e).__name__}:{e}"})
            traceback.print_exc(file=sys.stderr)
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"quick": args.quick, "rows": all_rows}, f, indent=2)
        print(f"# wrote {args.json}", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
