"""Benchmark harness (deliverable d): one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --only fig6,table2
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

BENCHES = [
    ("fig2a_gemm_gemv", "benchmarks.gemm_gemv", True),
    ("fig2b_draft_structures", "benchmarks.draft_structures", True),
    ("table2_domain_acceptance", "benchmarks.domain_acceptance", True),
    ("fig3b_confidence", "benchmarks.confidence_acceptance", True),
    ("fig6_offline_serving", "benchmarks.offline_serving", True),
    ("fig7_online_serving", "benchmarks.online_serving", True),
    ("table3_cost_efficiency", "benchmarks.cost_efficiency", True),
    ("ablation", "benchmarks.ablation", True),
    ("kernels", "benchmarks.kernel_bench", False),
    ("roofline", "benchmarks.roofline", False),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", type=str, default=None,
                    help="comma-separated substring filters")
    ap.add_argument("--skip-fixture", action="store_true",
                    help="run only benches that need no trained models")
    args = ap.parse_args()
    only = args.only.split(",") if args.only else None

    selected = [(n, m, f) for n, m, f in BENCHES
                if only is None or any(o in n for o in only)]
    needs_fixture = any(f for _, _, f in selected) and not args.skip_fixture

    fixture = None
    if needs_fixture:
        from benchmarks.common import build_fixture
        t0 = time.time()
        print(f"# building/loading benchmark fixture...", file=sys.stderr)
        fixture = build_fixture(verbose=True)
        print(f"# fixture ready in {time.time() - t0:.0f}s", file=sys.stderr)

    print("name,us_per_call,derived")
    failures = 0
    for name, modname, needs_fx in selected:
        if needs_fx and fixture is None:
            continue
        try:
            mod = __import__(modname, fromlist=["run"])
            rows = mod.run(fixture) if needs_fx else mod.run()
            for r in rows:
                print(f"{r[0]},{r[1]:.1f},{r[2]}")
            sys.stdout.flush()
        except Exception as e:
            failures += 1
            print(f"{name},0.0,ERROR:{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
