"""§Roofline: reads the dry-run JSONs (launch/dryrun.py output) and prints
the three-term roofline per (arch x shape x mesh): compute / memory /
collective seconds, dominant term, and the useful-FLOPs ratio
MODEL_FLOPS / HLO_FLOPS (6ND dense, 6·N_active·D MoE)."""
from __future__ import annotations

import glob
import json
import os
import time

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def load_results(dryrun_dir: str = DRYRUN_DIR):
    out = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def table(results, mesh="16x16", step_filter=None):
    rows = []
    for r in results:
        if r["mesh"] != mesh:
            continue
        if step_filter and r["step"] != step_filter:
            continue
        rl = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "step": r["step"],
            "compute_s": rl["compute_s"], "memory_s": rl["memory_s"],
            "collective_s": rl["collective_s"], "dominant": rl["dominant"],
            "useful": r.get("useful_flops_ratio"),
        })
    return rows


def run(fixture=None):
    t0 = time.time()
    results = load_results()
    rows = []
    for r in table(results, mesh="16x16"):
        us = (time.time() - t0) * 1e6 / max(len(results), 1)
        useful = f";useful={r['useful']:.3f}" if r["useful"] else ""
        rows.append((
            f"roofline_{r['arch']}_{r['shape']}_{r['step']}", us,
            f"compute_s={r['compute_s']:.3e};memory_s={r['memory_s']:.3e};"
            f"collective_s={r['collective_s']:.3e};dom={r['dominant']}"
            + useful))
    n_multi = sum(1 for r in results if r["mesh"] == "2x16x16")
    rows.append(("roofline_multipod_lowered", 0.0,
                 f"combos_ok={n_multi}"))
    if not rows:
        rows.append(("roofline_missing", 0.0,
                     "run launch/dryrun.py --all first"))
    return rows
