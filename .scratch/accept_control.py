import numpy as np
from repro.config import CoSineConfig
from repro.configs.drafters import tiny_target
from repro.data.synthetic import SyntheticCorpus
from repro.launch.train import train_model
from repro.serving.engine import SpeculativeEngine

V = 128
corpus = SyntheticCorpus(V, seed=0)
tcfg = tiny_target(V)
tparams, _ = train_model(tcfg, corpus, None, steps=60, batch=8, seq=48, verbose=False)

# drafter == target: every draft token must be accepted (gamma+1 per iter)
drafters = [(tcfg, tparams, "self")]
cos = CoSineConfig(n_drafters=1, draft_len=4, drafters_per_request=1, tree_width=0)
eng = SpeculativeEngine((tcfg, tparams), drafters, cos, strategy="vanilla", max_len=256, seed=0)
p, dom = corpus.prompts(1, 12, seed=7)[0]
eng.submit(p, max_new_tokens=20, domain=dom)
st = eng.run()
print(f"iters={len(st.records)} committed={st.total_committed} acc/iter={st.mean_acceptance:.2f}")
assert st.mean_acceptance > 4.0, "self-drafting should accept all gamma+1 tokens"
print("CONTROL OK: self-drafting accepts gamma+1 per iteration")
