import jax, jax.numpy as jnp, numpy as np
from repro.config import CoSineConfig
from repro.configs.drafters import tiny_target, tiny_drafter
from repro.data.synthetic import SyntheticCorpus, DOMAINS
from repro.launch.train import train_model
from repro.serving.engine import SpeculativeEngine
from repro.models import model as M

V = 128
corpus = SyntheticCorpus(V, seed=0)
tcfg = tiny_target(V)
tparams, tl = train_model(tcfg, corpus, None, steps=60, batch=8, seq=48, verbose=False)
print("target loss", tl[0], "->", tl[-1])
dcfg = tiny_drafter(V)
drafters = []
for i, dom in enumerate(DOMAINS[:3]):
    dp, dl = train_model(dcfg, corpus, dom, steps=40, batch=8, seq=48, seed=i+1, verbose=False)
    drafters.append((dcfg, dp, dom))
    print(f"drafter {dom} loss {dl[0]:.3f}->{dl[-1]:.3f}")

cos = CoSineConfig(n_drafters=3, draft_len=4, drafters_per_request=2, tree_width=2)
eng = SpeculativeEngine((tcfg, tparams), drafters, cos, strategy="cosine", max_len=256, seed=0)
prompts = corpus.prompts(4, 16, seed=3)
for p, dom in prompts:
    eng.submit(p, max_new_tokens=24, domain=dom)
stats = eng.run()
print("iterations:", len(stats.records), "committed:", stats.total_committed, "mean acc/iter:", stats.mean_acceptance)

params, cfg = tparams, tcfg
for r in eng.pool.completed:
    ctx = list(r.prompt)
    ref = []
    cache = M.init_cache(cfg, 1, 256, dtype=jnp.float32)
    lg, cache, _ = M.prefill(params, cfg, jnp.asarray(ctx)[None, :], cache)
    last = np.asarray(lg[0, -1, :cfg.vocab])
    for _ in range(r.max_new_tokens):
        t = int(np.argmax(last))
        ref.append(t)
        lg, cache, _ = M.decode_step(params, cfg, jnp.asarray([[t]]), cache)
        last = np.asarray(lg[0, 0, :cfg.vocab])
    assert r.generated == ref, (r.rid, r.generated[:10], ref[:10])
print("LOSSLESSNESS OK: speculative output == greedy AR for all requests")
