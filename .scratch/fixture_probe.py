"""Probe: does the sharp fixture give paper-range acceptance (1.7-3.2)?"""
import sys, time
sys.path.insert(0, "benchmarks")
from common import build_fixture

t0 = time.time()
fx = build_fixture(verbose=True)
print(f"fixture in {time.time()-t0:.0f}s")

# in-domain vs cross-domain acceptance, drafter 0 (piqa)
from repro.config import CoSineConfig
for dom in ["piqa", "medqa"]:
    eng = fx.engine("vanilla", n_drafters=1)
    for p, d in [(pp, dd) for pp, dd in fx.corpus.prompts(6, 16, seed=3) if dd == dom][:3]:
        eng.submit(p, max_new_tokens=32, domain=d)
    st = eng.run()
    per_req = st.total_committed / max(sum(r.n_iterations for r in eng.pool.completed), 1)
    print(f"drafter=piqa domain={dom}: acc tokens/iter/request = {per_req:.2f}")
