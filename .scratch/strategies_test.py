import numpy as np
from repro.config import CoSineConfig
from repro.configs.drafters import tiny_target, tiny_drafter
from repro.data.synthetic import SyntheticCorpus, DOMAINS
from repro.launch.train import train_model
from repro.serving.engine import SpeculativeEngine, STRATEGIES

V = 128
corpus = SyntheticCorpus(V, seed=0)
tcfg = tiny_target(V)
tparams, _ = train_model(tcfg, corpus, None, steps=60, batch=8, seq=48, verbose=False)
dcfg = tiny_drafter(V)
drafters = []
for i, dom in enumerate(DOMAINS[:3]):
    dp, _ = train_model(dcfg, corpus, dom, steps=40, batch=8, seq=48, seed=i + 1, verbose=False)
    drafters.append((dcfg, dp, dom))

prompts = corpus.prompts(3, 12, seed=7)
outputs = {}
for strat in STRATEGIES:
    cos = CoSineConfig(n_drafters=3, draft_len=4, drafters_per_request=2, tree_width=2)
    eng = SpeculativeEngine((tcfg, tparams), drafters, cos, strategy=strat, max_len=256, seed=0)
    for p, dom in prompts:
        eng.submit(p, max_new_tokens=16, domain=dom)
    st = eng.run()
    outs = {tuple(r.prompt.tolist()): r.generated for r in eng.pool.completed}
    outputs[strat] = outs
    print(f"{strat:10s} iters={len(st.records):3d} committed={st.total_committed} "
          f"acc/iter={st.mean_acceptance:.2f} sim_ms={st.sim_ms:.1f} tput={st.throughput_tps:.1f} tok/s")

ref = outputs["ar"]
for strat in STRATEGIES[1:]:
    assert outputs[strat] == ref, f"{strat} output differs from AR!"
print("ALL STRATEGIES LOSSLESS: identical outputs")
