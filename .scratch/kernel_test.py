import jax, jax.numpy as jnp, numpy as np
from repro.kernels.tree_attention.ops import tree_attention
from repro.kernels.tree_attention.ref import tree_attention_ref
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref

key = jax.random.PRNGKey(0)

def rand(*s, k=0, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(k), s, dtype)

# tree attention: B=2, Hkv=2, R=12 rows, S=40 cache, M=12 seg
B,H,R,S,Msz,Dk,Dv = 2,2,12,40,12,32,16
q = rand(B,H,R,Dk,k=1)
kc = rand(B,H,S,Dk,k=2); vc = rand(B,H,S,Dv,k=3)
ks = rand(B,H,Msz,Dk,k=4); vs = rand(B,H,Msz,Dv,k=5)
cache_pos = jnp.broadcast_to(jnp.arange(S),(B,S)).astype(jnp.int32)
cache_pos = jnp.where(cache_pos < 30, cache_pos, -1)  # 30 valid
q_pos = 30 + jnp.broadcast_to(jnp.arange(R)//2, (B,R)).astype(jnp.int32)
seg_mask = jax.random.bernoulli(jax.random.PRNGKey(6), 0.5, (B,R,Msz))
seg_mask = seg_mask | jnp.eye(R,Msz,dtype=bool)
out = tree_attention(q,kc,vc,cache_pos,ks,vs,q_pos,seg_mask,scale=0.2,interpret=True)
ref = tree_attention_ref(q,kc,vc,cache_pos,ks,vs,q_pos,seg_mask,scale=0.2)
np.testing.assert_allclose(np.asarray(out),np.asarray(ref),rtol=2e-5,atol=2e-5)
print("tree_attention == ref OK", out.shape)

# with window
out = tree_attention(q,kc,vc,cache_pos,ks,vs,q_pos,seg_mask,scale=0.2,window=16,interpret=True)
ref = tree_attention_ref(q,kc,vc,cache_pos,ks,vs,q_pos,seg_mask,scale=0.2,window=16)
np.testing.assert_allclose(np.asarray(out),np.asarray(ref),rtol=2e-5,atol=2e-5)
print("tree_attention window OK")

# decode attention
G = 8
q2 = rand(B,H,G,Dk,k=7)
q_pos2 = jnp.array([29, 25], jnp.int32)
out = decode_attention(q2,kc,vc,cache_pos,q_pos2,scale=0.2,interpret=True,block_k=16)
ref = decode_attention_ref(q2,kc,vc,cache_pos,q_pos2,scale=0.2)
np.testing.assert_allclose(np.asarray(out),np.asarray(ref),rtol=2e-5,atol=2e-5)
print("decode_attention == ref OK", out.shape)
out = decode_attention(q2,kc,vc,cache_pos,q_pos2,scale=0.2,window=8,interpret=True,block_k=16)
ref = decode_attention_ref(q2,kc,vc,cache_pos,q_pos2,scale=0.2,window=8)
np.testing.assert_allclose(np.asarray(out),np.asarray(ref),rtol=2e-5,atol=2e-5)
print("decode_attention window OK")
