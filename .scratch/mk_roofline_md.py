"""Render the §Roofline table + hillclimb sections into EXPERIMENTS.md."""
import glob
import json
import sys

rows = []
variants = {}
for p in sorted(glob.glob("experiments/dryrun/*.json")):
    r = json.load(open(p))
    key = (r["arch"], r["shape"], r["step"], r.get("variant", ""))
    if r["mesh"] == "16x16":
        if r.get("variant"):
            variants[key] = r
        else:
            rows.append(r)

lines = ["| arch | shape | step | compute_s | memory_s | collective_s | dominant | useful (6ND/analytic) |",
         "|---|---|---|---|---|---|---|---|"]
for r in sorted(rows, key=lambda x: (x["arch"], x["shape"], x["step"])):
    rl = r["roofline"]
    u = r.get("useful_flops_ratio")
    lines.append(
        f"| {r['arch']} | {r['shape']} | {r['step']} | {rl['compute_s']:.3e} "
        f"| {rl['memory_s']:.3e} | {rl['collective_s']:.3e} | "
        f"{rl['dominant']} | {u:.2f} |" if u else
        f"| {r['arch']} | {r['shape']} | {r['step']} | {rl['compute_s']:.3e} "
        f"| {rl['memory_s']:.3e} | {rl['collective_s']:.3e} | "
        f"{rl['dominant']} | - |")
n_multi = len([p for p in glob.glob("experiments/dryrun/*.json")
               if json.load(open(p))["mesh"] == "2x16x16"])
lines.append("")
lines.append(f"Multi-pod (2x16x16): {n_multi} combos lowered+compiled OK "
             "(same JSON directory).")
table = "\n".join(lines)

md = open("EXPERIMENTS.md").read()
md = md.replace("<!-- ROOFLINE_TABLE -->", table)
open("EXPERIMENTS.md", "w").write(md)
print(f"inserted {len(rows)} baseline rows, {len(variants)} variant rows")
