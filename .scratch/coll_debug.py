import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import jax, re, math
from collections import defaultdict
from repro.launch.dryrun import make_step, resolve_config
from repro.launch.mesh import make_production_mesh
from repro.analysis.hlo import (parse_computations, COLLECTIVES, _SHAPE_RE,
                                DTYPE_BYTES, _TRIP)

arch, shape, kind, variant = sys.argv[1], sys.argv[2], sys.argv[3], \
    (sys.argv[4] if len(sys.argv) > 4 else "")
mesh = make_production_mesh()
cfg = resolve_config(arch, shape, variant)
fn, args, shards = make_step(cfg, shape, mesh, kind, variant)
with mesh:
    hlo = jax.jit(fn, in_shardings=tuple(shards)).lower(*args).compile().as_text()

comps = parse_computations(hlo)
entry = comps.pop("__entry__")[0]
callers = defaultdict(list); direct = defaultdict(list)
for name, lines in comps.items():
    for line in lines:
        rhs = line.split("=", 1)[1] if "=" in line else line
        cf = None
        for c in COLLECTIVES:
            if re.search(rf"\b{c}(-start)?\(", rhs):
                cf = c; break
        if cf:
            head = rhs.split(cf)[0]
            nb = sum(math.prod([int(d) for d in dims.split(',') if d] or [1])
                     * DTYPE_BYTES[dt] for dt, dims in _SHAPE_RE.findall(head))
            meta = re.search(r'op_name="([^"]*)"', line)
            direct[name].append((cf, nb, meta.group(1)[-90:] if meta else "?"))
            continue
        trip = 1
        tm = _TRIP.search(line)
        if tm:
            trip = int(tm.group(1))
        for kw, mult in (("body", trip), ("condition", trip),
                         ("to_apply", 1), ("calls", 1)):
            for callee in re.findall(rf"{kw}=%?([\w.\-]+)", line):
                callers[callee].append((name, mult))
memo = {}
def mult_of(c):
    if c == entry:
        return 1.0
    if c in memo:
        return memo[c]
    memo[c] = 0.0
    memo[c] = sum(mult_of(p) * m for p, m in callers.get(c, [])) or 1.0
    return memo[c]
rows = []
for name, cols in direct.items():
    for c, nb, meta in cols:
        rows.append((nb * max(mult_of(name), 1), c, nb, mult_of(name), meta))
rows.sort(reverse=True)
tot = sum(r[0] for r in rows)
print(f"TOTAL corrected bytes/dev: {tot:.3e}  ({len(rows)} collectives)")
for t, c, nb, m, meta in rows[:14]:
    print(f"{t:.3e} {c:<18} base={nb:.2e} x{m:<6.0f} {meta}")
