"""Scheduler (Eq. 5-8 / Alg. 2) and routing (Eq. 1-3) properties."""
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:     # declared dep; degrade so collection never hard-fails
    from _hypothesis_fallback import given, settings, st

from repro.config import CoSineConfig
from repro.core.latency_model import LatencyModel
from repro.core.request_pool import RequestPool
from repro.core.routing import AdaptiveRouter, routing_score, \
    verification_accuracy
from repro.core.scheduler import RequestScheduler, adaptive_speculation


@given(st.lists(st.integers(1, 16), min_size=1, max_size=12),
       st.integers(1, 64))
@settings(max_examples=50, deadline=None)
def test_adaptive_speculation_budget(gammas, budget):
    out = adaptive_speculation(gammas, budget, min_gamma=1)
    assert len(out) == len(gammas)
    assert all(1 <= g for g in out)
    assert all(o <= g for o, g in zip(out, gammas))
    # either within budget or every gamma already at the floor
    assert sum(out) <= budget or all(g == 1 for g in out)


@given(st.lists(st.integers(1, 16), min_size=1, max_size=12),
       st.integers(1, 64), st.integers(1, 4))
@settings(max_examples=50, deadline=None)
def test_adaptive_speculation_min_gamma_floor(gammas, budget, min_gamma):
    out = adaptive_speculation(gammas, budget, min_gamma=min_gamma)
    # never decremented below the floor (inputs already below it pass through)
    assert all(o >= min(g, min_gamma) for o, g in zip(out, gammas))
    assert all(o <= g for o, g in zip(out, gammas))
    # budget respected unless every trimmable gamma sits at the floor
    assert sum(out) <= budget or all(o <= min_gamma for o in out)


def _mk_requests(n, lens, arrivals=None):
    pool = RequestPool()
    rs = []
    for i in range(n):
        r = pool.add(np.zeros(lens[i], np.int32), 32,
                     arrival_ms=(arrivals[i] if arrivals else 0.0))
        r.gamma = 4
        rs.append(r)
    return rs


def test_plan_respects_constraints():
    cfg = CoSineConfig(max_batch=4, gamma_max_total=10, t_max_ms=1e9)
    sched = RequestScheduler(cfg, LatencyModel())
    rs = _mk_requests(8, [10, 20, 30, 40, 50, 60, 70, 80])
    plan = sched.plan(rs)
    assert 1 <= len(plan.requests) <= 4
    assert plan.big_gamma <= 10
    assert all(g >= 1 for g in plan.gammas)
    # length-sorted prefix property
    sel_lens = [r.context_len for r in plan.requests]
    assert sel_lens == sorted(sel_lens)


def test_plan_slo_fallback():
    cfg = CoSineConfig(max_batch=4, t_max_ms=0.001)   # infeasible SLO
    sched = RequestScheduler(cfg, LatencyModel())
    rs = _mk_requests(3, [10, 20, 30])
    plan = sched.plan(rs)
    assert len(plan.requests) == 1      # serves the shortest alone


@given(st.integers(0, 10_000), st.integers(1, 10), st.integers(1, 4),
       st.integers(2, 32))
@settings(max_examples=30, deadline=None)
def test_plan_invariants(seed, n_req, min_gamma, budget):
    rng = np.random.default_rng(seed)
    cfg = CoSineConfig(max_batch=4, gamma_max_total=budget,
                       min_gamma=min_gamma, t_max_ms=1e9)
    sched = RequestScheduler(cfg, LatencyModel())
    rs = _mk_requests(n_req, rng.integers(4, 200, n_req).tolist())
    for r in rs:
        r.gamma = int(rng.integers(1, 9))
    gamma_before = {r.rid: r.gamma for r in rs}
    plan = sched.plan(rs)
    assert 1 <= len(plan.requests) <= cfg.max_batch
    assert len(plan.gammas) == len(plan.requests)
    # token budget respected unless every gamma was trimmed to the floor
    assert plan.big_gamma <= budget or all(g <= min_gamma
                                           for g in plan.gammas)
    assert all(g >= min(min_gamma, gamma_before[r.rid])
               for r, g in zip(plan.requests, plan.gammas))
    assert all(g <= gamma_before[r.rid]
               for r, g in zip(plan.requests, plan.gammas))
    # planning must not mutate request state
    assert all(r.gamma == gamma_before[r.rid] for r in rs)
    # candidate batches are length-sorted prefixes
    sel = [r.context_len for r in plan.requests]
    assert sel == sorted(sel)
    unselected = [r.context_len for r in rs if r not in plan.requests]
    assert max(sel) <= min(unselected, default=max(sel))


@given(st.integers(0, 10_000), st.integers(1, 8))
@settings(max_examples=30, deadline=None)
def test_plan_slo_infeasible_returns_exactly_one(seed, n_req):
    rng = np.random.default_rng(seed)
    cfg = CoSineConfig(max_batch=4, t_max_ms=1e-9)    # nothing fits the SLO
    sched = RequestScheduler(cfg, LatencyModel())
    lens = rng.integers(4, 200, n_req).tolist()
    rs = _mk_requests(n_req, lens)
    plan = sched.plan(rs)
    assert len(plan.requests) == 1 and len(plan.gammas) == 1
    assert plan.gammas[0] >= cfg.min_gamma
    assert plan.requests[0].context_len == min(lens)   # shortest served alone


def test_balance_gamma_monotone_in_verify_cost():
    cfg = CoSineConfig()
    lat = LatencyModel()
    sched = RequestScheduler(cfg, lat)
    g_small = sched.balance_gamma(1, 100)
    g_big = sched.balance_gamma(16, 20000)   # pricier verification
    assert g_big >= g_small >= 1


@given(st.lists(st.floats(0.01, 0.99), min_size=1, max_size=8),
       st.lists(st.floats(0.0, 1.0), min_size=1, max_size=8))
@settings(max_examples=50, deadline=None)
def test_routing_score_in_unit_interval(conf, acc):
    n = min(len(conf), len(acc))
    s = routing_score(np.array(conf[:n]), np.array(acc[:n]))
    assert 0.0 <= s <= 1.0


def test_routing_score_monotone():
    lo = routing_score(np.array([0.2, 0.2]), np.array([0.2, 0.2]))
    hi = routing_score(np.array([0.9, 0.9]), np.array([0.9, 0.9]))
    assert hi > lo


def test_verification_accuracy_zero_beyond_acceptance():
    embed = np.random.default_rng(0).normal(size=(10, 4)).astype(np.float32)
    d = verification_accuracy(embed, np.array([1, 2, 3, 4]), [1, 2])
    assert d.shape == (4,)
    assert d[2] == 0.0 and d[3] == 0.0
    assert d[0] > 0.99  # same token -> cos = 1


def test_router_update_and_route():
    cfg = CoSineConfig(n_drafters=4, drafters_per_request=2, alpha=0.5,
                       beta=0.9, tau=2.0)
    embed = np.random.default_rng(0).normal(size=(16, 8)).astype(np.float32)
    router = AdaptiveRouter(4, cfg, embed, seed=0)
    toks = np.zeros((4, 3), np.int64)
    toks[2] = [1, 2, 3]                       # drafter 2 matches accepted
    conf = np.full((4, 3), 0.9, np.float32)
    for _ in range(8):
        router.update(0, toks, conf, [1, 2, 3], participated=[0, 1, 2, 3])
    m = router.vector(0)
    assert m[2] == max(m)                     # accurate drafter scores highest
    picks = [tuple(router.route(0, l_acc=10.0)) for _ in range(30)]
    # exploitation mode mostly includes the best drafter
    frac_best = np.mean([2 in p for p in picks])
    assert frac_best > 0.6
    assert all(len(p) == 2 for p in picks)


# ------------------------------------------------ SLO / admission planning
def test_plan_aging_prevents_starvation():
    """Candidate truncation (4*max_batch) used to starve a long request
    behind a stream of short fresh arrivals; arrival-age credit pulls it
    to the front of the sort."""
    lens = [8] * 12 + [190]
    arrivals = [10_000.0] * 12 + [0.0]       # the long one is old
    fresh = CoSineConfig(max_batch=2, t_max_ms=1e9, age_tok_per_ms=0.0)
    plan = RequestScheduler(fresh, LatencyModel()).plan(
        _mk_requests(13, lens, arrivals), now_ms=10_500.0)
    def old_req(p):
        return [r for r in p.requests if r.context_len == 190]

    assert not old_req(plan)                 # without aging: starved
    aged = CoSineConfig(max_batch=2, t_max_ms=1e9, age_tok_per_ms=0.05)
    plan = RequestScheduler(aged, LatencyModel()).plan(
        _mk_requests(13, lens, arrivals), now_ms=10_500.0)
    assert old_req(plan)                     # with aging: selected


def test_plan_aging_priority_bonus():
    """Priority class 0 ages faster than class 2: with equal arrivals,
    the high-priority long request is credited ahead."""
    cfg = CoSineConfig(max_batch=1, t_max_ms=1e9, age_tok_per_ms=0.05,
                       priority_age_bonus_ms=2000.0)
    rs = _mk_requests(2, [100, 10])
    rs[0].priority = 0                       # long but high class
    rs[1].priority = 2
    plan = RequestScheduler(cfg, LatencyModel()).plan(rs, now_ms=0.0)
    assert plan.requests == [rs[0]]


def test_effective_lam_clamped_and_deadbanded():
    from repro.core.scheduler import PipelineObservation as Obs
    cfg = CoSineConfig(max_batch=4)
    sched = RequestScheduler(cfg, LatencyModel())
    lam = sched.effective_lam
    base = lam(Obs(verify_busy_frac=0.9, draft_busy_frac=0.5))
    assert base == cfg.lam
    # queue pressure raises lambda but is clamped at lam_mult_max
    jam = lam(Obs(verify_busy_frac=1.0, draft_busy_frac=0.5,
                  queue_depth=500))
    assert jam == cfg.lam * cfg.lam_mult_max
    # monotone non-decreasing in queue depth up to the clamp
    seq = [lam(Obs(verify_busy_frac=1.0, draft_busy_frac=0.5,
                   queue_depth=q)) for q in range(0, 12)]
    assert all(b >= a for a, b in zip(seq, seq[1:]))
    # starved verifier discounts; the deadband keeps the setpoint stable
    assert lam(Obs(verify_busy_frac=0.3, draft_busy_frac=0.3)) \
        == cfg.lam * 0.5
    assert lam(Obs(verify_busy_frac=0.78, draft_busy_frac=0.3)) == cfg.lam
    # ... but not when speculation is already saturated (draft more
    # would change nothing) or the backlog exceeds a batch
    assert lam(Obs(verify_busy_frac=0.3, draft_busy_frac=0.3,
                   spec_saturated=True)) == cfg.lam
    assert lam(Obs(verify_busy_frac=0.3, draft_busy_frac=0.3,
                   backlog=5)) == cfg.lam
    # hot drafter node with verifier slack trims speculation (verify at
    # 0.8: above the starved threshold so only the drafter signal fires)
    assert lam(Obs(verify_busy_frac=0.8, draft_busy_frac=0.99)) \
        == cfg.lam * 2.0


def test_balance_gamma_capped_at_gamma_max():
    cfg = CoSineConfig(gamma_max=6)
    # drafting can never cover verification: capped, saturation flagged
    fast = LatencyModel(ssm_step_ms=0.001, ssm_ctx_ms_per_ktok=0.0,
                        ssm_batch_ms=0.0)
    sched = RequestScheduler(cfg, fast)
    assert sched.balance_gamma(1, 100) == 6
    assert sched.spec_saturated
    # a slow drafter covers at gamma=1: no saturation
    slow = LatencyModel(ssm_step_ms=1000.0)
    sched = RequestScheduler(cfg, slow)
    assert sched.balance_gamma(1, 100) == 1
    assert not sched.spec_saturated


def test_slo_gamma_trims_with_shrinking_headroom():
    cfg = CoSineConfig(min_gamma=1, gamma_max=16)
    sched = RequestScheduler(cfg, LatencyModel())
    pool = RequestPool()
    r = pool.add(np.zeros(32, np.int32), 32, deadline_ms=1e9)
    r.gamma = 8
    assert sched.slo_gamma(r, now_ms=0.0) == 8       # ample headroom
    r.deadline_ms = float("inf")
    assert sched.slo_gamma(r, now_ms=0.0) == 8       # no SLO set
    # monotone: gamma never grows as the deadline approaches
    r.deadline_ms = 1e9
    gs = [sched.slo_gamma(r, now_ms=1e9 - h)
          for h in (1e9, 1e6, 1e4, 2e3, 500.0, 0.0)]
    assert all(b <= a for a, b in zip(gs, gs[1:]))
    assert gs[-1] == cfg.min_gamma                   # overdue: floor
    # trimming never raises a gamma already below min_gamma
    cfg2 = CoSineConfig(min_gamma=4, gamma_max=16)
    sched2 = RequestScheduler(cfg2, LatencyModel())
    r.gamma = 2
    assert sched2.slo_gamma(r, now_ms=1e9) == 2
