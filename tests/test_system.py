"""End-to-end system behaviour: the losslessness invariant (speculative
serving emits exactly the target's greedy continuation) across every
strategy, plus engine bookkeeping."""
import jax.numpy as jnp
import numpy as np
import pytest

# every test here consumes the session-scoped trained_tiny fixture (trains
# a target + 3 drafters) — excluded from the fast `-m "not slow"` loop
pytestmark = pytest.mark.slow

from repro.config import CoSineConfig
from repro.models import model as M
from repro.serving.engine import STRATEGIES, SpeculativeEngine


def _greedy_reference(cfg, params, prompt, n, max_len=256):
    cache = M.init_cache(cfg, 1, max_len, dtype=jnp.float32)
    lg, cache, _ = M.prefill(params, cfg, jnp.asarray(prompt)[None, :], cache)
    last = np.asarray(lg[0, -1, :cfg.vocab])
    out = []
    for _ in range(n):
        t = int(np.argmax(last))
        out.append(t)
        lg, cache, _ = M.decode_step(params, cfg, jnp.asarray([[t]]), cache)
        last = np.asarray(lg[0, 0, :cfg.vocab])
    return out


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_strategy_lossless(strategy, trained_tiny):
    tcfg, tparams = trained_tiny["target"]
    cos = CoSineConfig(n_drafters=3, draft_len=4, drafters_per_request=2,
                       tree_width=2)
    eng = SpeculativeEngine((tcfg, tparams), trained_tiny["drafters"], cos,
                            strategy=strategy, max_len=256, seed=0)
    prompts = trained_tiny["corpus"].prompts(3, 12, seed=5)
    for p, dom in prompts:
        eng.submit(p, max_new_tokens=12, domain=dom)
    stats = eng.run()
    assert eng.pool.empty
    assert len(eng.pool.completed) == 3
    assert stats.total_committed == 36
    for r in eng.pool.completed:
        ref = _greedy_reference(tcfg, tparams, r.prompt, 12)
        assert r.generated == ref, strategy


def test_online_arrivals_respected(trained_tiny):
    tcfg, tparams = trained_tiny["target"]
    cos = CoSineConfig(n_drafters=3, draft_len=3, drafters_per_request=2)
    eng = SpeculativeEngine((tcfg, tparams), trained_tiny["drafters"], cos,
                            strategy="cosine", max_len=256, seed=0)
    prompts = trained_tiny["corpus"].prompts(3, 10, seed=9)
    arrivals = [0.0, 500.0, 10_000.0]
    for (p, dom), t in zip(prompts, arrivals):
        eng.submit(p, max_new_tokens=8, domain=dom, arrival_ms=t)
    eng.run()
    assert len(eng.pool.completed) == 3
    for r in eng.pool.completed:
        assert r.finish_ms >= r.arrival_ms
        assert r.first_token_ms >= r.arrival_ms


def test_engine_acceptance_bookkeeping(trained_tiny):
    tcfg, tparams = trained_tiny["target"]
    cos = CoSineConfig(n_drafters=3, draft_len=4, drafters_per_request=2)
    eng = SpeculativeEngine((tcfg, tparams), trained_tiny["drafters"], cos,
                            strategy="cosine", max_len=256, seed=0)
    p, dom = trained_tiny["corpus"].prompts(1, 10, seed=11)[0]
    eng.submit(p, max_new_tokens=10, domain=dom)
    stats = eng.run()
    r = eng.pool.completed[0]
    assert r.n_iterations == len(stats.records)
    assert r.n_accepted_total == 10
    assert stats.sim_ms > 0
    assert stats.throughput_tps > 0
