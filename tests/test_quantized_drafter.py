"""Weight-only int8 drafter path (DESIGN.md §2.9): per-output-channel
symmetric quantization of drafter weights, the qdot dispatch that lets
the same step functions run quantized params, the fused int8 GEMV decode
kernel against its oracle, the checkpoint calibrate-then-swap hook, and
— the serving claim — mixed-precision heterogeneous pools whose
committed streams stay greedy-exact: quantization may only change which
drafts are proposed, never what the target commits."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import TINY_MAX_LEN as MAX_LEN, tiny_model_cfg as _tiny
from repro.config import CoSineConfig, MLAConfig, ModelConfig, MoEConfig
from repro.core.latency_model import (DrafterProfile, INT8_DRAFT_SPEED,
                                      pool_profiles)
from repro.kernels.int8_gemv.ops import int8_gemv, int8_gemv_xla
from repro.kernels.int8_gemv.ref import int8_gemv_ref
from repro.models import model as M
from repro.models.quantize import (dequantize_weight, embed_lookup,
                                   is_quantized, qdot, quantize_params,
                                   quantize_weight, resolve_drafter_quant,
                                   tied_logits)
from repro.serving.engine import SpeculativeEngine


# ------------------------------------------------------------ quantize units
def test_quantize_roundtrip_error_bound():
    """Per-output-channel symmetric int8: the dequantized weight is
    within half a quantization step (absmax/254) of the original, per
    column."""
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 48)) * 0.3
    q = quantize_weight(w)
    assert q["w8"].dtype == jnp.int8 and q["w8"].shape == w.shape
    assert q["scale"].shape == (1, 48)
    err = jnp.abs(dequantize_weight(q) - w)
    bound = jnp.max(jnp.abs(w), axis=0, keepdims=True) / 254.0
    assert bool(jnp.all(err <= bound + 1e-7))


def test_quantize_weight_zero_column():
    """An all-zero output channel must not divide by zero and must
    round-trip to exactly zero."""
    w = jnp.zeros((8, 3)).at[:, 0].set(1.0)
    q = quantize_weight(w)
    np.testing.assert_array_equal(np.asarray(dequantize_weight(q)[:, 1:]),
                                  0.0)


def test_qdot_plain_is_bitwise_plain_matmul():
    """Unquantized params take the identical `x @ w` path — bitwise, so
    every pre-existing byte-identity test still holds through qdot."""
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 32))
    w = jax.random.normal(jax.random.PRNGKey(2), (32, 24))
    np.testing.assert_array_equal(np.asarray(qdot(x, w)), np.asarray(x @ w))


def test_qdot_quant_matches_dequantized_matmul():
    x = jax.random.normal(jax.random.PRNGKey(3), (5, 32))
    w = jax.random.normal(jax.random.PRNGKey(4), (32, 24))
    q = quantize_weight(w)
    np.testing.assert_allclose(np.asarray(qdot(x, q)),
                               np.asarray(x @ dequantize_weight(q)),
                               rtol=1e-5, atol=1e-5)


def test_embed_lookup_and_tied_logits_quantized():
    emb = jax.random.normal(jax.random.PRNGKey(5), (50, 32)) * 0.02
    toks = jnp.asarray([[1, 4, 49], [0, 2, 7]])
    q = quantize_weight(emb, axis=-1)          # per-row (per-token) scales
    assert q["scale"].shape == (50, 1)
    deq = dequantize_weight(q)
    np.testing.assert_allclose(
        np.asarray(embed_lookup(q, toks, jnp.float32)),
        np.asarray(deq[toks]), rtol=1e-6, atol=1e-6)
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 3, 32))
    np.testing.assert_allclose(np.asarray(tied_logits(q, x)),
                               np.asarray(x @ deq.T), rtol=1e-4, atol=1e-4)


def test_quantize_params_idempotent_and_typed():
    cfg = _tiny("attn")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    qp = quantize_params(params, cfg)
    assert is_quantized(qp["embed"])
    mixer = qp["stages"][0][0]["mixer"]
    assert all(is_quantized(mixer[k]) for k in ("wq", "wk", "wv", "wo"))
    # norms stay plain f32
    assert not is_quantized(qp["stages"][0][0]["ln1"])
    qp2 = quantize_params(qp, cfg)
    np.testing.assert_array_equal(np.asarray(qp2["embed"]["w8"]),
                                  np.asarray(qp["embed"]["w8"]))


def test_quantize_params_rejects_mla():
    from test_runner_slots import _tiny_exotic
    cfg = _tiny_exotic("mla")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="MLA"):
        quantize_params(params, cfg)


def test_quantize_params_skips_moe_ffn():
    """MoE expert weights feed lax.ragged_dot (plain arrays only): the
    router/expert leaves pass through unquantized, attention still
    quantizes."""
    cfg = ModelConfig(name="tiny-moe", family="moe", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128,
                      vocab=50, tie_embeddings=True, dtype="float32",
                      moe=MoEConfig(n_routed=4, top_k=2, d_ff=64))
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    qp = quantize_params(params, cfg)
    sub = qp["stages"][0][0]
    assert is_quantized(sub["mixer"]["wq"])
    moe_ffn = sub["ffn"]
    assert "router" in moe_ffn
    assert not any(is_quantized(v) for v in moe_ffn.values())


# ------------------------------------------------------------- int8 GEMV
def test_int8_gemv_kernel_bitwise_vs_oracle_aligned():
    """Tile-aligned shape: the Pallas kernel (interpret mode) tiles N
    only, one full-K dot per tile — the same reduction order as the
    oracle's single dot, so equality is bitwise, not allclose."""
    x = jax.random.normal(jax.random.PRNGKey(7), (8, 256), jnp.float32)
    w8 = jax.random.randint(jax.random.PRNGKey(8), (256, 384), -127, 128,
                            jnp.int8)
    scale = jax.random.uniform(jax.random.PRNGKey(9), (1, 384),
                               minval=0.001, maxval=0.02)
    got = int8_gemv(x, w8, scale, interpret=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(int8_gemv_ref(x, w8, scale)))


def test_int8_gemv_kernel_unaligned_allclose():
    """Unaligned (B, K, N): the wrapper zero-pads to tile multiples; the
    padded-K tail may reorder the SIMD reduction, so the contract
    degrades to allclose."""
    x = jax.random.normal(jax.random.PRNGKey(10), (3, 100), jnp.float32)
    w8 = jax.random.randint(jax.random.PRNGKey(11), (100, 70), -127, 128,
                            jnp.int8)
    scale = jnp.full((1, 70), 0.01, jnp.float32)
    want = int8_gemv_ref(x, w8, scale)
    got = int8_gemv(x, w8, scale, interpret=True)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(int8_gemv_xla(x, w8, scale)),
                               np.asarray(want), rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------- checkpoint
def test_checkpoint_quantize_on_load(tmp_path):
    from repro.checkpoint.store import load_checkpoint, save_checkpoint
    cfg = _tiny("attn")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    path = str(tmp_path / "ck.msgpack")
    save_checkpoint(path, params, {"loss": 1.0})
    qp, meta = load_checkpoint(path, quantize="int8")
    want = quantize_params(params, cfg)
    np.testing.assert_array_equal(np.asarray(qp["embed"]["w8"]),
                                  np.asarray(want["embed"]["w8"]))
    # an already-quantized checkpoint round-trips and passes through
    qpath = str(tmp_path / "ck8.msgpack")
    save_checkpoint(qpath, qp, meta)
    qp2, _ = load_checkpoint(qpath, quantize="int8")
    np.testing.assert_array_equal(np.asarray(qp2["embed"]["w8"]),
                                  np.asarray(qp["embed"]["w8"]))
    with pytest.raises(ValueError, match="quantize"):
        load_checkpoint(path, quantize="fp4")


# ----------------------------------------------------- forward, all families
@pytest.mark.parametrize("kind", ["attn", "ssm", "hybrid"])
def test_quantized_forward_runs_and_tracks_plain(kind):
    """The same prefill/decode step functions run quantized params for
    every mixer family; argmax tokens track the unquantized model on a
    random init (weights are small, so quantization noise rarely flips
    the argmax)."""
    cfg = _tiny(kind)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    qp = quantize_params(params, cfg)
    toks = jnp.asarray([[1, 5, 9, 2, 7, 3]])
    c1 = M.init_cache(cfg, 1, MAX_LEN, dtype=jnp.float32)
    c2 = M.init_cache(cfg, 1, MAX_LEN, dtype=jnp.float32)
    lg, c1, _ = M.prefill(params, cfg, toks, c1)
    qlg, c2, _ = M.prefill(qp, cfg, toks, c2)
    assert qlg.shape == lg.shape
    agree = float(jnp.mean((jnp.argmax(lg[..., :cfg.vocab], -1)
                            == jnp.argmax(qlg[..., :cfg.vocab], -1))
                           .astype(jnp.float32)))
    assert agree >= 0.5
    step = jnp.asarray([[4]])
    qlg2, _, _ = M.decode_step(qp, cfg, step, c2)
    assert qlg2.shape[:2] == (1, 1)


# ------------------------------------------------- pool config / profiles
def test_resolve_drafter_quant_per_node_overrides():
    cfg = _tiny("attn")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    drafters = [(cfg, params, "a"),                              # inherit
                (cfg.with_overrides(quant="none"), params, "b"),  # pinned
                (cfg.with_overrides(quant="int8"), params, "c")]
    out = resolve_drafter_quant(drafters, pool_default="int8")
    assert [c.quant for c, _, _ in out] == ["int8", "none", "int8"]
    assert is_quantized(out[0][1]["embed"])
    assert not is_quantized(out[1][1]["embed"])
    assert is_quantized(out[2][1]["embed"])
    speeds = [p.speed for p in pool_profiles([c for c, _, _ in out])]
    assert speeds == [INT8_DRAFT_SPEED, 1.0, INT8_DRAFT_SPEED]


# ------------------------------------------------- engine losslessness
def _greedy_reference(cfg, params, prompt, n):
    cache = M.init_cache(cfg, 1, MAX_LEN, dtype=jnp.float32)
    lg, cache, _ = M.prefill(params, cfg, jnp.asarray(prompt)[None, :], cache)
    last = np.asarray(lg[0, -1, :cfg.vocab])
    out = []
    for _ in range(n):
        t = int(np.argmax(last))
        out.append(t)
        lg, cache, _ = M.decode_step(params, cfg, jnp.asarray([[t]]), cache)
        last = np.asarray(lg[0, 0, :cfg.vocab])
    return out


def _mixed_drafters(vocab=50):
    dcfg = ModelConfig(name="tiny-draft", family="dense", n_layers=1,
                       d_model=48, n_heads=2, n_kv_heads=2, head_dim=16,
                       d_ff=96, vocab=vocab, tie_embeddings=True,
                       dtype="float32")
    return [(dcfg.with_overrides(quant="int8"),
             M.init_params(jax.random.PRNGKey(1), dcfg), "d0"),
            (dcfg, M.init_params(jax.random.PRNGKey(2), dcfg), "d1"),
            (dcfg, M.init_params(jax.random.PRNGKey(3), dcfg), "d2")]


def _run_lossless(target, drafters, profiles=None, **cos_kw):
    tcfg, tparams = target
    cos = CoSineConfig(n_drafters=len(drafters), draft_len=4,
                       drafters_per_request=2, tree_width=2, **cos_kw)
    eng = SpeculativeEngine(target, drafters, cos, strategy="cosine",
                            max_len=MAX_LEN, seed=0,
                            drafter_profiles=profiles)
    rng = np.random.default_rng(3)
    for i in range(3):
        eng.submit(rng.integers(1, tcfg.vocab, 8).tolist(),
                   max_new_tokens=10, arrival_ms=float(i * 5))
    reqs = eng.pool.pending(float("inf"))
    eng.run()
    assert all(r.done for r in reqs)
    for r in reqs:
        assert r.generated == _greedy_reference(tcfg, tparams,
                                                list(r.prompt),
                                                len(r.generated))
    return eng


@pytest.mark.parametrize("family", ["attn", "ssm"])
def test_mixed_pool_greedy_exact(family):
    """One int8 drafter beside two bf16 drafters, quorum fusion on:
    committed streams equal the target's greedy reference exactly —
    attention and SSM targets. The engine's default profiles must price
    the int8 node at INT8_DRAFT_SPEED."""
    tcfg = _tiny(family)
    tparams = M.init_params(jax.random.PRNGKey(0), tcfg)
    eng = _run_lossless((tcfg, tparams), _mixed_drafters())
    assert [p.speed for p in eng.drafter_profiles] == [INT8_DRAFT_SPEED,
                                                       1.0, 1.0]
    assert eng.stats.draft_calls > 0


@pytest.mark.parametrize("policy", ["side", "drop"])
def test_mixed_pool_lossless_under_straggler_cut(policy):
    """The int8 node drafts on while an 8x always-straggling bf16 node
    is cut from every cohort (side-branched or dropped): committed
    tokens still match greedy exactly, redrafts and all."""
    tcfg = _tiny("attn")
    tparams = M.init_params(jax.random.PRNGKey(0), tcfg)
    profiles = (DrafterProfile(speed=INT8_DRAFT_SPEED),
                DrafterProfile(speed=8.0, straggle_prob=1.0,
                               straggle_factor=5.0),
                DrafterProfile(speed=1.0))
    _run_lossless((tcfg, tparams), _mixed_drafters(), profiles=profiles,
                  straggler_policy=policy)


def test_cluster_calibration_recovers_int8_pace():
    """calibrated_profiles() refits node speed from measured (b, l,
    step_ms) observations: after a mixed-pool run the int8 node's
    fitted speed is INT8_DRAFT_SPEED, the bf16 nodes' 1.0."""
    tcfg = _tiny("attn")
    tparams = M.init_params(jax.random.PRNGKey(0), tcfg)
    eng = _run_lossless((tcfg, tparams), _mixed_drafters())
    cal = eng.executor.cluster.calibrated_profiles(min_jobs=2)
    assert cal[0].speed == pytest.approx(INT8_DRAFT_SPEED, rel=0.05)
    for p in cal[1:]:
        if p.jitter_frac == 0.0 and p.speed != 1.0:
            continue        # node kept its configured profile (few jobs)
        assert p.speed == pytest.approx(1.0, rel=0.05)


@pytest.mark.slow
def test_trained_mixed_pool_lossless(trained_tiny):
    """The trained fixture: quantizing a trained drafter genuinely moves
    its proposal distribution (acceptance may change), yet committed
    streams stay greedy-exact under quorum fusion — the losslessness-by-
    construction claim at realistic acceptance rates."""
    tcfg, tparams = trained_tiny["target"]
    d = trained_tiny["drafters"]
    mixed = [(d[0][0].with_overrides(quant="int8"), d[0][1], d[0][2])] \
        + list(d[1:])
    cos = CoSineConfig(n_drafters=len(mixed), draft_len=5,
                       drafters_per_request=2, tree_width=2)
    eng = SpeculativeEngine((tcfg, tparams), mixed, cos, strategy="cosine",
                            max_len=256, seed=0)
    prompts = trained_tiny["corpus"].prompts(4, 12, seed=5)
    for i, (p, dom) in enumerate(prompts):
        eng.submit(p, max_new_tokens=12, domain=dom, arrival_ms=float(i * 3))
    reqs = eng.pool.pending(float("inf"))
    eng.run()
    assert all(r.done for r in reqs)
    from benchmarks.common import greedy_reference
    for r in reqs:
        assert r.generated == greedy_reference(tcfg, tparams,
                                               list(r.prompt),
                                               len(r.generated),
                                               max_len=256)
    # the int8 drafter's proposals really differ from its bf16 self:
    # same engine seed, bf16 pool — acceptance accounting must diverge
    eng2 = SpeculativeEngine((tcfg, tparams), list(d), cos,
                             strategy="cosine", max_len=256, seed=0)
    for i, (p, dom) in enumerate(prompts):
        eng2.submit(p, max_new_tokens=12, domain=dom, arrival_ms=float(i * 3))
    eng2.run()
    assert (eng.stats.draft_calls, eng.stats.total_committed) \
        != (eng2.stats.draft_calls, eng2.stats.total_committed) \
        or eng.stats.mean_acceptance != eng2.stats.mean_acceptance
