"""Route-faithful sub-batched drafting (DESIGN.md §2.4): each drafter
decodes only its routed sub-batch.

Covers the tentpole's equivalence obligation — with parts = all nodes
(specinfer) or fusion-on routed parts, the sub-batched path commits
token-identical streams to the legacy full fan-out — plus the routed
compute accounting (per-node drafted tokens = routed sub-batch size x
gamma), the participants-only routing evidence property, losslessness
under always-straggling nodes with sub-batching on, the per-component
lock-step sync, and the drafter-profile auto-calibration fit."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:     # declared dep; degrade so collection never hard-fails
    from _hypothesis_fallback import given, settings, st

from conftest import TINY_MAX_LEN as MAX_LEN, tiny_model_cfg as _tiny
from repro.config import CoSineConfig, ModelConfig
from repro.core.latency_model import DrafterProfile, LatencyModel
from repro.core.routing import AdaptiveRouter
from repro.models import model as M
from repro.serving.cluster import DrafterCluster
from repro.serving.engine import SpeculativeEngine
from repro.serving.events import EventLog


@pytest.fixture(scope="module")
def models():
    tcfg = _tiny("attn")
    scfg = _tiny("ssm")
    key = jax.random.PRNGKey(0)
    tparams = M.init_params(key, tcfg)
    sparams = M.init_params(key, scfg)
    dcfg = ModelConfig(name="tiny-draft", family="dense", n_layers=1,
                       d_model=48, n_heads=2, n_kv_heads=2, head_dim=16,
                       d_ff=96, vocab=50, tie_embeddings=True,
                       dtype="float32")
    drafters = [(dcfg, M.init_params(jax.random.PRNGKey(i + 1), dcfg), f"d{i}")
                for i in range(3)]
    return {"attn": (tcfg, tparams), "ssm": (scfg, sparams),
            "drafters": drafters}


def _engine(models, family, strategy, subbatch=True, seed=0, profiles=None,
            **cos_kw):
    cos = CoSineConfig(n_drafters=3, draft_len=4, drafters_per_request=2,
                       tree_width=2, subbatch_drafting=subbatch, **cos_kw)
    return SpeculativeEngine(models[family], models["drafters"], cos,
                             strategy=strategy, max_len=MAX_LEN, seed=seed,
                             drafter_profiles=profiles)


def _submit(eng, n=4, seed=3, max_new=10):
    rng = np.random.default_rng(seed)
    for i in range(n):
        eng.submit(rng.integers(1, 50, 8).tolist(), max_new_tokens=max_new,
                   arrival_ms=float(i * 5))


def _greedy_reference(cfg, params, prompt, n):
    cache = M.init_cache(cfg, 1, MAX_LEN, dtype=jnp.float32)
    lg, cache, _ = M.prefill(params, cfg, jnp.asarray(prompt)[None, :], cache)
    last = np.asarray(lg[0, -1, :cfg.vocab])
    out = []
    for _ in range(n):
        t = int(np.argmax(last))
        out.append(t)
        lg, cache, _ = M.decode_step(params, cfg, jnp.asarray([[t]]), cache)
        last = np.asarray(lg[0, 0, :cfg.vocab])
    return out


# ------------------------------------------- sub-batch vs full-fanout tokens
@pytest.mark.parametrize("family", ["attn", "ssm"])
@pytest.mark.parametrize("strategy", ["cosine", "specinfer"])
def test_subbatch_matches_fanout_committed_tokens(models, family, strategy):
    """With parts = all nodes (specinfer) or fusion-on routed parts
    (cosine), sub-batched drafting must match the legacy full fan-out —
    for attention and SSM targets (the SSM case exercises
    recurrent-state snapshots per sub-batch).

    Stream equality alone would be vacuous (losslessness guarantees the
    target's greedy continuation whatever the drafts contain), so the
    per-iteration acceptance counts and drafted tree volumes — which DO
    depend on drafted content — must match too. The fan-out engine flips
    only the drafting path (`eng.cfg`) after construction; its scheduler
    keeps the sub-batch planning cfg, so cohort composition is identical
    and any divergence is the sub-batched token path itself."""
    import dataclasses
    runs = []
    for fanout in (False, True):
        eng = _engine(models, family, strategy)
        if fanout:
            eng.cfg = dataclasses.replace(eng.cfg, subbatch_drafting=False)
        _submit(eng)
        eng.run()
        runs.append((
            {r.rid: list(r.generated) for r in eng.pool.completed},
            [rec.committed for rec in eng.stats.records],
            [rec.big_gamma for rec in eng.stats.records],
            eng.stats.draft_calls))
    (gen_s, com_s, gg_s, dc_s), (gen_f, com_f, gg_f, dc_f) = runs
    assert gen_s == gen_f                 # bit-identical committed streams
    assert com_s == com_f                 # per-iteration acceptance counts
    assert gg_s == gg_f                   # per-iteration verified volumes
    if strategy == "cosine":
        assert dc_s < dc_f                # routing really cut the compute
    else:
        assert dc_s == dc_f               # specinfer: full fan-out either way


def test_subbatch_drafts_identical_proposals_under_fusion(models):
    """Stronger than stream equality: with fusion on and fixed parts, the
    participants' drafted proposals (tokens, confidences, consumed
    chains) are bitwise equal between the sub-batched and fan-out paths,
    and non-participant chain rows carry the fused chain."""
    entries = {}
    for subbatch in (True, False):
        eng = _engine(models, "attn", "cosine", subbatch=subbatch)
        _submit(eng, n=3)
        batch = eng.pool.pending(float("inf"))
        for r in batch:
            eng._ensure_prefilled(r)
        parts = [[0, 1], [1, 2], [2, 0]]
        entries[subbatch] = eng._draft_entries(batch, [4] * 3, parts=parts)
    for a, b, p in zip(entries[True], entries[False],
                       [[0, 1], [1, 2], [2, 0]]):
        np.testing.assert_array_equal(a.fused_t, b.fused_t)
        np.testing.assert_array_equal(a.d_toks[p], b.d_toks[p])
        np.testing.assert_array_equal(a.d_confs[p], b.d_confs[p])
        np.testing.assert_array_equal(a.d_chains, b.d_chains)
        (miss,) = [i for i in range(3) if i not in p]
        np.testing.assert_array_equal(a.d_chains[miss], a.fused_t)


# ------------------------------------------------------ compute accounting
def test_node_drafted_equals_subbatch_size_times_gamma(models):
    """Each node's drafted-token count must equal its routed sub-batch
    size times the draft length — the route-faithful compute the fig7
    `dtoks`/`draft_calls` columns report."""
    eng = _engine(models, "attn", "cosine")
    _submit(eng, n=3)
    batch = eng.pool.pending(float("inf"))
    for r in batch:
        eng._ensure_prefilled(r)
    parts = [[0, 1], [1], [1, 2]]
    gam = 4
    eng._draft_entries(batch, [gam] * 3, parts=parts)
    sizes = [sum(1 for p in parts if di in p) for di in range(3)]
    assert eng.stats.node_drafted == [s * gam for s in sizes]
    assert eng.stats.draft_calls == sum(sizes) * gam


def test_routed_drafting_cheaper_than_fanout(models):
    """End to end, routed sub-batches must cost fewer drafter
    token-decodes than the same workload under full fan-out (k=2 of 3
    nodes -> roughly two thirds)."""
    calls = {}
    for subbatch in (True, False):
        eng = _engine(models, "attn", "cosine", subbatch=subbatch)
        _submit(eng)
        eng.run()
        calls[subbatch] = eng.stats.draft_calls
    assert 0 < calls[True] < calls[False]


# ------------------------------------------- routing evidence: participants
@given(st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_router_update_ignores_nonparticipant_rows(seed):
    """Eq. 1-2 evidence must come only from a request's participants:
    the update result is invariant to whatever sits in non-participant
    rows (zeros under sub-batched drafting, live tokens under fan-out)."""
    rng = np.random.default_rng(seed)
    n, K, V = 4, 5, 32
    cfg = CoSineConfig(n_drafters=n)
    embed = rng.standard_normal((V, 8)).astype(np.float32)
    parts = sorted(rng.choice(n, size=2, replace=False).tolist())
    accepted = rng.integers(0, V, rng.integers(1, K + 1)).tolist()
    toks = rng.integers(0, V, (n, K)).astype(np.int64)
    confs = rng.random((n, K)).astype(np.float32)
    out = []
    for fill in (0, 1):
        r = AdaptiveRouter(n, cfg, embed, seed=0)
        t, c = toks.copy(), confs.copy()
        others = [i for i in range(n) if i not in parts]
        if fill:    # scramble the non-participant rows
            t[others] = rng.integers(0, V, (len(others), K))
            c[others] = rng.random((len(others), K))
        else:       # sub-batched drafting leaves them zeroed
            t[others] = 0
            c[others] = 0.0
        out.append(r.update(7, t, c, accepted, parts).copy())
    np.testing.assert_array_equal(out[0], out[1])


# ------------------------------------------------- losslessness, stragglers
EXTREME = (DrafterProfile(speed=1.0),
           DrafterProfile(speed=8.0, straggle_prob=1.0, straggle_factor=5.0),
           DrafterProfile(speed=1.1))


@pytest.mark.parametrize("family", ["attn", "ssm"])
@pytest.mark.parametrize("policy", ["side", "drop"])
def test_subbatch_lossless_under_always_straggling_node(models, family,
                                                        policy):
    """Unconditional losslessness with sub-batched drafting: an 8x
    always-straggling node (cut from every cohort, its sub-batch chains
    demoted or dropped) must not change a single committed token vs the
    target's greedy continuation — attention and SSM targets."""
    tcfg, tparams = models[family]
    eng = _engine(models, family, "cosine", profiles=EXTREME,
                  straggler_policy=policy)
    _submit(eng, n=3, max_new=12)
    reqs = eng.pool.pending(float("inf"))
    eng.run()
    assert all(r.done for r in reqs)
    for r in reqs:
        ref = _greedy_reference(tcfg, tparams, list(r.prompt),
                                len(r.generated))
        assert r.generated == ref
    assert eng.stats.draft_calls > 0


# ------------------------------------------------- per-component lock-step
def test_lockstep_sync_only_between_nodes_sharing_requests():
    """Two on-time nodes with disjoint sub-batches must not wait for each
    other: the faster component finishes before the slower one, whereas
    a shared request forces the common lock-step pace."""
    profiles = (DrafterProfile(speed=1.0), DrafterProfile(speed=1.5))
    cfg = CoSineConfig(n_drafters=2, cut_pace_slack=2.0)
    lat = LatencyModel()

    def ends(parts_by_req):
        cl = DrafterCluster(profiles, lat, cfg, EventLog(), seed=0)
        plan = cl.plan_cohort(parts_by_req, l=64, gamma=4, gate_ms=0.0)
        return {d.node: d.end_ms for d in plan.drafts}

    disjoint = ends({0: [0], 1: [1]})
    shared = ends({0: [0, 1], 1: [1]})
    assert disjoint[0] < disjoint[1]            # own pace per component
    assert shared[0] == shared[1]               # lock-step when coupled
    assert disjoint[0] < shared[0]              # no cross-component wait
    assert disjoint[1] <= shared[1]             # smaller sync term


# -------------------------------------------------------- auto-calibration
def test_calibrated_profiles_fit_speed_and_jitter():
    """`DrafterCluster.calibrated_profiles` must recover a node's speed
    multiplier from its measured per-job paces (fit-style, like fit_ssm)
    and report ~zero jitter for a jitter-free node while a noisy node
    calibrates a positive jitter_frac."""
    profiles = (DrafterProfile(speed=1.0),
                DrafterProfile(speed=2.5, jitter_frac=0.2),
                DrafterProfile(speed=4.0))
    cfg = CoSineConfig(n_drafters=3)
    cl = DrafterCluster(profiles, LatencyModel(), cfg, EventLog(), seed=1)
    rng = np.random.default_rng(0)
    t = 0.0
    for k in range(40):
        parts = {100 + k: [0, 1, 2], 101 + k: [int(rng.integers(0, 3))]}
        plan = cl.plan_cohort(parts, l=32 + 4 * (k % 7), gamma=4, gate_ms=t)
        cl.commit_cohort(plan, kind="draft")
        t = plan.ready_ms
    fit = cl.calibrated_profiles()
    assert abs(fit[0].speed - 1.0) < 0.05
    assert abs(fit[1].speed - 2.5) / 2.5 < 0.2
    assert abs(fit[2].speed - 4.0) / 4.0 < 0.05
    assert fit[0].jitter_frac < 0.02 and fit[2].jitter_frac < 0.02
    assert fit[1].jitter_frac > 0.05


def test_calibrated_profiles_keep_unobserved_nodes():
    profiles = (DrafterProfile(speed=3.0), DrafterProfile(speed=1.0))
    cfg = CoSineConfig(n_drafters=2)
    cl = DrafterCluster(profiles, LatencyModel(), cfg, EventLog(), seed=0)
    for k in range(6):
        plan = cl.plan_cohort({200 + k: [1]}, l=48, gamma=3,
                              gate_ms=float(k))
        cl.commit_cohort(plan, kind="draft")
    fit = cl.calibrated_profiles()
    assert fit[0] == profiles[0]                # no jobs -> no refit
    assert abs(fit[1].speed - 1.0) < 0.05
