"""Draft-tree construction + greedy tree acceptance properties."""
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:     # declared dep; degrade so collection never hard-fails
    from _hypothesis_fallback import given, settings, st

from repro.core.tree import (accept_tree_greedy, build_tree, chain_tree,
                             pad_trees)


def test_chain_tree_ancestor_mask_is_lower_triangular():
    t = chain_tree([5, 6, 7, 8])
    m = t.ancestor_mask()
    assert np.array_equal(m, np.tril(np.ones((4, 4), bool)))


def test_build_tree_dedups_fused_token():
    side_t = np.array([[9, 5], [6, 3]])   # depth 0: {9,5}; depth 1: {6,3}
    side_p = np.array([[0.9, 0.8], [0.7, 0.3]])
    side_d = np.array([[0, 1], [0, 1]])
    t = build_tree(np.array([5, 6]), np.array([0.5, 0.5]),
                   side_t, side_p, side_d, tree_width=2)
    # fused tokens 5(d0),6(d1); side: 9 at d0 (5 deduped), 3 at d1 (6 deduped)
    assert t.chain_len == 2
    assert sorted(t.tokens.tolist()) == [3, 5, 6, 9]
    side_nodes = [i for i in range(t.n_nodes) if t.drafter[i] >= 0]
    for i in side_nodes:
        assert t.parent[i] == t.depth[i] - 1


def test_build_tree_skips_masked_side_columns():
    """A masked column (prob < 0: non-participant drafter / dropped
    chain) must contribute no side branch, even when the depth has fewer
    than tree_width real candidates — its token is not a proposal."""
    side_t = np.array([[9, 42], [6, 0]])
    side_p = np.array([[0.9, -1.0], [0.7, -1.0]])   # column 1 masked
    side_d = np.array([[0, 1], [0, 1]])
    t = build_tree(np.array([5, 6]), np.array([0.5, 0.5]),
                   side_t, side_p, side_d, tree_width=2)
    assert 42 not in t.tokens.tolist() and 0 not in t.tokens.tolist()
    assert sorted(t.tokens.tolist()) == [5, 6, 9]   # 6 deduped at depth 1
    assert all(p >= 0 for p in t.prob.tolist())


def test_accept_tree_walks_main_chain():
    t = chain_tree([5, 6, 7])
    node_argmax = np.array([6, 7, 9])   # after 5 target wants 6, etc.
    toks, path, corr = accept_tree_greedy(t, node_argmax, entry_argmax=5)
    assert toks == [5, 6, 7]
    assert corr == 9


def test_accept_tree_takes_side_branch():
    side_t = np.array([[4]])
    side_p = np.array([[0.9]])
    side_d = np.array([[1]])
    t = build_tree(np.array([5]), np.array([0.5]), side_t, side_p, side_d, 1)
    # entry wants 4 (the side candidate), not the fused 5
    node_argmax = np.zeros(t.n_nodes, np.int64)
    side_idx = [i for i in range(t.n_nodes) if t.tokens[i] == 4][0]
    node_argmax[side_idx] = 8
    toks, path, corr = accept_tree_greedy(t, node_argmax, entry_argmax=4)
    assert toks == [4]
    assert corr == 8


@given(st.integers(0, 10_000), st.integers(1, 6), st.integers(1, 3))
@settings(max_examples=25, deadline=None)
def test_tree_invariants(seed, K, width):
    rng = np.random.default_rng(seed)
    V = 10
    chain = rng.integers(0, V, K)
    side_t = rng.integers(0, V, (K, 3))
    side_p = rng.random((K, 3)).astype(np.float32)
    side_d = np.broadcast_to(np.arange(3), (K, 3))
    t = build_tree(chain, rng.random(K), side_t, side_p, side_d, width)
    # parents precede children; depths consistent; side nodes are leaves
    for i in range(t.n_nodes):
        p = t.parent[i]
        assert p < i
        if p >= 0:
            assert t.depth[i] == t.depth[p] + 1
        else:
            assert t.depth[i] == 0
    assert t.n_nodes <= K + K * width
    # acceptance result is always a valid root-path of the tree + correction
    node_argmax = rng.integers(0, V, t.n_nodes)
    toks, path, corr = accept_tree_greedy(t, node_argmax,
                                          int(rng.integers(0, V)))
    assert len(toks) == len(path) <= t.n_nodes
    for j, node in enumerate(path):
        assert t.depth[node] == j


def test_pad_trees_batches():
    ts = [chain_tree([1, 2]), chain_tree([3, 4, 5])]
    p = pad_trees(ts, 4)
    assert p["tokens"].shape == (2, 4)
    assert p["valid"][0].tolist() == [True, True, False, False]
    assert p["mask"][0, 2, 2] and p["mask"][0, 3, 3]  # padded self-attend
