"""Multi-node drafter cluster (serving/cluster.py, DESIGN.md §2.4):
per-drafter clock determinism under a fixed seed, straggler cut-off
losslessness, and the occupancy-vs-event-log accounting property."""
import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:     # declared dep; degrade so collection never hard-fails
    from _hypothesis_fallback import given, settings, st

from conftest import TINY_MAX_LEN as MAX_LEN, tiny_model_cfg as _tiny
from repro.config import CoSineConfig, ModelConfig
from repro.core.latency_model import (DrafterProfile, LatencyModel,
                                      homogeneous_profiles)
from repro.core.routing import AdaptiveRouter
from repro.serving.cluster import DROPPED, FUSED, SIDE, DrafterCluster
from repro.serving.engine import SpeculativeEngine
from repro.serving.events import EventLog, StageClock


HETERO = (DrafterProfile(speed=1.0),
          DrafterProfile(speed=2.4, comm_ms=2.0, jitter_frac=0.3,
                         straggle_prob=0.5, straggle_factor=3.0))
EXTREME = (DrafterProfile(speed=1.0),
           DrafterProfile(speed=8.0, straggle_prob=1.0, straggle_factor=5.0))


def test_unscheduled_stage_clock_reads_zero_occupancy():
    """Regression: a StageClock that never ran any work must report 0.0
    busy fraction. The old 0/0 fallback read 1.0, which made never-used
    drafter nodes look saturated to the scheduler's first observation."""
    clk = StageClock("draft0", EventLog())
    assert clk.busy_frac() == 0.0
    # parking (arrival lull) accrues no idle and still reads 0.0
    clk.park(500.0)
    assert clk.busy_frac() == 0.0
    # after real work the fraction is measured as before
    clk.schedule(10.0, not_before_ms=510.0)
    assert abs(clk.busy_frac() - 0.5) < 1e-12


# ------------------------------------------------------------ pure cluster
def _mk_cluster(profiles, seed=0, **cfg_kw):
    cfg = CoSineConfig(n_drafters=len(profiles), **cfg_kw)
    return DrafterCluster(profiles, LatencyModel(), cfg, EventLog(),
                          seed=seed)


def _drive(cluster, n_cohorts=6, seed=0):
    """Plan+commit a deterministic stream of cohorts; returns the trace."""
    rng = np.random.default_rng(seed)
    t = 0.0
    for k in range(n_cohorts):
        n = len(cluster.nodes)
        parts = {100 + 2 * k: sorted(rng.choice(n, size=min(2, n),
                                                replace=False).tolist()),
                 101 + 2 * k: [int(rng.integers(0, n))]}
        plan = cluster.plan_cohort(parts, l=64 + 8 * k, gamma=4, gate_ms=t,
                                   conf_signal=float(rng.random()))
        cluster.commit_cohort(plan, kind="draft")
        t = plan.fused_end_ms
    return cluster.log.trace()


def test_per_drafter_clock_determinism_fixed_seed():
    t1 = _drive(_mk_cluster(HETERO, seed=7))
    t2 = _drive(_mk_cluster(HETERO, seed=7))
    assert t1 == t2 and len(t1) > 0
    # per-node stages appear in the stream
    stages = {ev[2] for ev in t1}
    assert "draft0" in stages and "draft1" in stages
    # a different seed reshuffles the jitter stream (jitter_frac > 0)
    t3 = _drive(_mk_cluster(HETERO, seed=8))
    assert t3 != t1


def test_fastest_node_never_cut_and_roles_partition():
    cluster = _mk_cluster(EXTREME)
    plan = cluster.plan_cohort({1: [0, 1], 2: [1]}, l=64, gamma=4,
                               gate_ms=0.0)
    roles = plan.roles()
    assert roles[0] == FUSED                     # fastest node anchors fusion
    assert roles[1] in (SIDE, DROPPED)           # 8x slow + straggle: cut
    # coverage rider: request 2's only drafter was cut, so it was
    # rerouted onto the fastest on-time node
    assert 0 in plan.parts_by_req[2]
    cluster.commit_cohort(plan)
    assert cluster.n_side + cluster.n_dropped == 1
    assert cluster.node_late[1] == 1 and cluster.node_late[0] == 0


def test_straggler_never_blocks_dispatch():
    """With recent confidence above the gate, the cohort ships with the
    fused group no matter how late the cut chain is; below the gate it
    waits at most the grace window for side chains — and every chain in
    the dispatched tree has arrived by ready_ms (causality)."""
    cluster = _mk_cluster(EXTREME, straggler_policy="drop")
    plan = cluster.plan_cohort({1: [0, 1]}, l=64, gamma=5, gate_ms=0.0,
                               conf_signal=0.99)
    sched = cluster.commit_cohort(plan)
    assert sched.dispatch_ms == sched.fused_end_ms
    included = [d for d in sched.drafts if d.role != DROPPED]
    assert sched.ready_ms == max(d.arrival_ms for d in included)

    cluster2 = _mk_cluster(HETERO, seed=3)
    plan2 = cluster2.plan_cohort({1: [0, 1]}, l=64, gamma=5, gate_ms=0.0,
                                 conf_signal=0.0)
    sched2 = cluster2.commit_cohort(plan2)
    fused_arr = max(d.arrival_ms for d in sched2.drafts if d.role == FUSED)
    for d in sched2.drafts:
        if d.role == SIDE:
            assert d.arrival_ms <= fused_arr + sched2.grace_ms + 1e-9
        if d.role != DROPPED:
            assert d.arrival_ms <= sched2.ready_ms + 1e-9


@given(st.integers(0, 10_000), st.integers(1, 4), st.integers(1, 5),
       st.integers(1, 8))
@settings(max_examples=30, deadline=None)
def test_occupancy_sums_match_event_log(seed, n_nodes, n_cohorts, gamma):
    """Property: each node clock's busy time equals the sum of its
    (start, end) spans in the event log, roles partition the
    participants, and dispatch/ready ordering holds."""
    rng = np.random.default_rng(seed)
    profiles = tuple(DrafterProfile(
        speed=float(rng.uniform(0.5, 4.0)),
        jitter_frac=float(rng.uniform(0.0, 0.4)),
        straggle_prob=float(rng.uniform(0.0, 0.6)),
        straggle_factor=float(rng.uniform(1.5, 6.0)))
        for _ in range(n_nodes))
    cluster = _mk_cluster(profiles, seed=seed)
    t = 0.0
    for k in range(n_cohorts):
        parts = {}
        for rid in range(3):
            sz = int(rng.integers(1, n_nodes + 1))
            parts[10 * k + rid] = sorted(
                rng.choice(n_nodes, size=sz, replace=False).tolist())
        plan = cluster.plan_cohort(parts, l=int(rng.integers(8, 512)),
                                   gamma=gamma, gate_ms=t,
                                   conf_signal=float(rng.random()))
        roles = plan.roles()
        assert set(roles.values()) <= {FUSED, SIDE, DROPPED}
        assert any(r == FUSED for r in roles.values())
        for p in plan.parts_by_req.values():     # coverage rider invariant
            assert any(roles[i] == FUSED for i in p)
        sched = cluster.commit_cohort(plan)
        assert sched.ready_ms >= sched.dispatch_ms >= sched.fused_end_ms - 1e-9
        included = [d for d in sched.drafts if d.role != DROPPED]
        # causality: the cohort is ready only once every included chain
        # has physically arrived; per-link delay is paid exactly once
        assert abs(sched.ready_ms - max(d.arrival_ms for d in included)) \
            < 1e-9
        assert abs(sched.dispatch_ms - max(d.end_ms for d in included)) \
            < 1e-9
        fused_arr = max(d.arrival_ms for d in sched.drafts
                        if d.role == FUSED)
        for d in sched.drafts:
            if d.role == SIDE:
                assert d.arrival_ms <= fused_arr + sched.grace_ms + 1e-9
        t = sched.dispatch_ms
    # the accounting property: per-node clock busy == event-log span sum
    for i, clk in enumerate(cluster.nodes):
        starts = [ev.t_ms for ev in cluster.log.events
                  if ev.stage == f"draft{i}" and ev.kind.endswith("_start")]
        ends = [ev.t_ms for ev in cluster.log.events
                if ev.stage == f"draft{i}" and ev.kind.endswith("_end")]
        assert len(starts) == len(ends) == clk.n_jobs
        log_busy = sum(e - s for s, e in zip(sorted(starts), sorted(ends)))
        assert abs(log_busy - clk.busy_ms) < 1e-6
        assert clk.idle_ms >= -1e-9 and clk.wait_ms >= -1e-9


# --------------------------------------------------------- engine-level
def _init_params(cfg, key):
    from repro.models import model as M
    return M.init_params(key, cfg)


@pytest.fixture(scope="module")
def models():
    tcfg = _tiny("attn")
    scfg = _tiny("ssm")
    key = jax.random.PRNGKey(0)
    tparams = _init_params(tcfg, key)
    sparams = _init_params(scfg, key)
    dcfg = ModelConfig(name="tiny-draft", family="dense", n_layers=1,
                       d_model=48, n_heads=2, n_kv_heads=2, head_dim=16,
                       d_ff=96, vocab=50, tie_embeddings=True,
                       dtype="float32")
    drafters = [(dcfg, _init_params(dcfg, jax.random.PRNGKey(i + 1)), f"d{i}")
                for i in range(2)]
    return {"attn": (tcfg, tparams), "ssm": (scfg, sparams),
            "drafters": drafters}


def _greedy_reference(cfg, params, prompt, n):
    import jax.numpy as jnp
    from repro.models import model as M
    cache = M.init_cache(cfg, 1, MAX_LEN, dtype=jnp.float32)
    lg, cache, _ = M.prefill(params, cfg, jnp.asarray(prompt)[None, :], cache)
    last = np.asarray(lg[0, -1, :cfg.vocab])
    out = []
    for _ in range(n):
        t = int(np.argmax(last))
        out.append(t)
        lg, cache, _ = M.decode_step(params, cfg, jnp.asarray([[t]]), cache)
        last = np.asarray(lg[0, 0, :cfg.vocab])
    return out


def _engine(models, family, strategy, profiles, seed=0, **cos_kw):
    cos = CoSineConfig(n_drafters=2, draft_len=4, drafters_per_request=2,
                       tree_width=2, **cos_kw)
    return SpeculativeEngine(models[family], models["drafters"], cos,
                             strategy=strategy, max_len=MAX_LEN, seed=seed,
                             drafter_profiles=profiles)


def _prompts(n, rng_seed=3, length=8):
    rng = np.random.default_rng(rng_seed)
    return [rng.integers(1, 50, length).tolist() for _ in range(n)]


@pytest.mark.parametrize("policy", ["side", "drop"])
def test_straggler_cutoff_lossless_attn(models, policy):
    """Extreme straggler (8x slow, always straggling): its chains are cut
    from every cohort, and generation still equals the target's greedy
    continuation — losslessness holds regardless of who is cut."""
    tcfg, tparams = models["attn"]
    eng = _engine(models, "attn", "cosine", EXTREME,
                  straggler_policy=policy)
    for p, t in zip(_prompts(3, rng_seed=13), [0.0, 100.0, 400.0]):
        eng.submit(p, max_new_tokens=8, arrival_ms=t)
    stats = eng.run()
    assert eng.pool.empty and len(eng.pool.completed) == 3
    for r in eng.pool.completed:
        assert r.generated == _greedy_reference(tcfg, tparams, r.prompt, 8), \
            policy
    cl = eng.executor.cluster
    assert cl.n_side + cl.n_dropped > 0          # the straggler was cut
    if policy == "drop":
        assert cl.n_side == 0
    # records' per-node busy never exceeds what the clocks measured
    # (drained ahead-cohorts may leave clock busy unrecorded, never less)
    rec_busy = stats.drafter_busy_ms
    for i, clk in enumerate(cl.nodes):
        assert rec_busy[i] <= clk.busy_ms + 1e-6
    assert stats.n_straggler_side == sum(
        r.n_straggler_side for r in stats.records)


@pytest.mark.slow
def test_straggler_cutoff_lossless_ssm_target(models):
    """Chain-only trees (SSM verifier) with a cut straggler stay
    lossless too."""
    scfg, sparams = models["ssm"]
    eng = _engine(models, "ssm", "cosine", EXTREME)
    for p, t in zip(_prompts(3, rng_seed=17), [0.0, 90.0, 350.0]):
        eng.submit(p, max_new_tokens=8, arrival_ms=t)
    eng.run()
    assert eng.pool.empty
    for r in eng.pool.completed:
        assert r.generated == _greedy_reference(scfg, sparams, r.prompt, 8)
    assert eng.executor.cluster.n_side + eng.executor.cluster.n_dropped > 0


def test_hetero_engine_event_stream_deterministic(models):
    """Jittery heterogeneous cluster: a fixed engine seed reproduces the
    per-node event streams and the generated tokens byte-for-byte."""
    def trace(seed):
        eng = _engine(models, "attn", "cosine", HETERO, seed=seed)
        for p, t in zip(_prompts(3, rng_seed=19), [0.0, 80.0, 250.0]):
            eng.submit(p, max_new_tokens=6, arrival_ms=t)
        eng.run()
        gen = {tuple(r.prompt.tolist()): list(r.generated)
               for r in eng.pool.completed}
        return eng.executor.log.trace(), gen

    t1, g1 = trace(4)
    t2, g2 = trace(4)
    assert t1 == t2 and g1 == g2


def test_slow_node_bubble_below_sluggish_sync():
    """The acceptance direction: with a 2x slow second node, the cluster
    that cuts stragglers keeps the verifier better fed than a lock-step
    cluster forced to sync with the slow node (modeled by widening the
    pace slack so nothing is ever cut)."""
    lat = LatencyModel()
    cfg_cut = CoSineConfig(n_drafters=2, cut_pace_slack=1.6)
    cfg_sync = CoSineConfig(n_drafters=2, cut_pace_slack=1e9)
    profiles = (DrafterProfile(speed=1.0), DrafterProfile(speed=2.0))

    def fused_end(cfg):
        cl = DrafterCluster(profiles, lat, cfg, EventLog(), seed=0)
        plan = cl.plan_cohort({1: [0, 1], 2: [0, 1]}, l=64, gamma=5,
                              gate_ms=0.0)
        return plan.fused_end_ms

    assert fused_end(cfg_cut) < fused_end(cfg_sync)


def test_router_downweights_chronically_late_nodes():
    cfg = CoSineConfig(n_drafters=3, drafters_per_request=1, alpha=0.0,
                       beta=0.0, straggler_penalty=0.8)
    embed = np.random.default_rng(0).normal(size=(8, 4)).astype(np.float32)
    router = AdaptiveRouter(3, cfg, embed, seed=0)
    for _ in range(30):
        router.note_node_outcome(2, "dropped")
    # pure exploration (coef=0): the chronically-late node is rarely drawn
    picks = [router.route(0, l_acc=0.0)[0] for _ in range(200)]
    frac_late = np.mean([p == 2 for p in picks])
    assert frac_late < 0.15
    assert router.node_lag[2] > 0.9
    # exploitation order also discounts it
    router.scores[1] = np.array([0.5, 0.5, 0.55], np.float32)
    cfg2 = CoSineConfig(n_drafters=3, drafters_per_request=1, alpha=1.0,
                        beta=1.0, straggler_penalty=0.8)
    router.cfg = cfg2
    assert router.route(1, l_acc=0.0)[0] != 2


def test_homogeneous_profiles_default():
    profs = homogeneous_profiles(3)
    assert len(profs) == 3
    assert all(p.speed == 1.0 and p.jitter_frac == 0.0 for p in profs)
