"""Discrete-event pipeline executor (serving/pipeline.py, DESIGN.md §2):
losslessness of the decoupled strategies, draft-ahead
invalidation/survival, event-order determinism, and the emergent-overlap
accounting. Uses random-init tiny models — losslessness and the event
timeline do not require trained weights (rejections are just frequent),
which keeps most of this module in the fast loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import TINY_MAX_LEN as MAX_LEN, tiny_model_cfg as _tiny
from repro.config import CoSineConfig, ModelConfig
from repro.core.latency_model import LatencyModel
from repro.core.request_pool import RequestPool
from repro.core.scheduler import PipelineObservation, RequestScheduler
from repro.models import model as M
from repro.serving.engine import SpeculativeEngine
from repro.serving.events import EventLog, StageClock


@pytest.fixture(scope="module")
def models():
    tcfg = _tiny("attn")
    scfg = _tiny("ssm")
    key = jax.random.PRNGKey(0)
    tparams = M.init_params(key, tcfg)
    sparams = M.init_params(key, scfg)
    dcfg = ModelConfig(name="tiny-draft", family="dense", n_layers=1,
                       d_model=48, n_heads=2, n_kv_heads=2, head_dim=16,
                       d_ff=96, vocab=50, tie_embeddings=True,
                       dtype="float32")
    drafters = [(dcfg, M.init_params(jax.random.PRNGKey(i + 1), dcfg), f"d{i}")
                for i in range(2)]
    return {"attn": (tcfg, tparams), "ssm": (scfg, sparams),
            "drafters": drafters}


def _greedy_reference(cfg, params, prompt, n):
    cache = M.init_cache(cfg, 1, MAX_LEN, dtype=jnp.float32)
    lg, cache, _ = M.prefill(params, cfg, jnp.asarray(prompt)[None, :], cache)
    last = np.asarray(lg[0, -1, :cfg.vocab])
    out = []
    for _ in range(n):
        t = int(np.argmax(last))
        out.append(t)
        lg, cache, _ = M.decode_step(params, cfg, jnp.asarray([[t]]), cache)
        last = np.asarray(lg[0, 0, :cfg.vocab])
    return out


def _engine(models, family, strategy, seed=0, drafters=None, **cos_kw):
    cos = CoSineConfig(n_drafters=2, draft_len=4, drafters_per_request=2,
                       tree_width=2, **cos_kw)
    return SpeculativeEngine(models[family], drafters or models["drafters"],
                             cos, strategy=strategy, max_len=MAX_LEN,
                             seed=seed)


def _prompts(n, rng_seed=3, length=8):
    rng = np.random.default_rng(rng_seed)
    return [rng.integers(1, 50, length).tolist() for _ in range(n)]


# --------------------------------------------------------------- fast: events
def test_stageclock_accounting():
    clk = StageClock("verify", EventLog())
    s, e, gap = clk.schedule(10.0, not_before_ms=5.0)
    assert (s, e, gap) == (5.0, 15.0, 5.0)
    s, e, gap = clk.schedule(4.0, not_before_ms=0.0)   # already free at 15
    assert (s, e, gap) == (15.0, 19.0, 0.0)
    assert clk.busy_ms == 14.0 and clk.idle_ms == 5.0
    assert abs(clk.busy_frac() - 14.0 / 19.0) < 1e-12
    assert len(clk.log.events) == 4
    # global seq gives a deterministic total order even at equal times
    seqs = [ev.seq for ev in clk.log.events]
    assert seqs == sorted(seqs)


def test_observation_scales_speculation_budget_pressure():
    pool = RequestPool()
    rs = []
    for i in range(6):
        r = pool.add(np.zeros(10 + i, np.int32), 32)
        r.gamma = 8
        rs.append(r)
    sched = RequestScheduler(CoSineConfig(max_batch=4, lam=0.02), LatencyModel())
    free = sched.plan(rs, observation=PipelineObservation(
        verify_busy_frac=0.5, queue_depth=0))
    jammed = sched.plan(rs, observation=PipelineObservation(
        verify_busy_frac=1.3, queue_depth=2))
    # queue pressure must never *raise* the speculation volume
    assert jammed.big_gamma <= free.big_gamma


# --------------------------------------------------- fast: losslessness (attn)
@pytest.mark.parametrize("strategy", ["cosine", "pipeinfer"])
def test_pipelined_lossless_attn(models, strategy):
    tcfg, tparams = models["attn"]
    eng = _engine(models, "attn", strategy)
    arrivals = [0.0, 120.0, 700.0]
    for p, t in zip(_prompts(3), arrivals):
        eng.submit(p, max_new_tokens=8, arrival_ms=t)
    stats = eng.run()
    assert eng.pool.empty and len(eng.pool.completed) == 3
    for r in eng.pool.completed:
        assert r.generated == _greedy_reference(tcfg, tparams, r.prompt, 8), \
            strategy
    assert stats.total_committed == 24
    # stage-level records are populated and internally consistent
    for rec in stats.records:
        assert rec.verify_ms > 0 and rec.draft_ms > 0
        assert rec.verify_start_ms >= rec.draft_start_ms
        assert rec.verify_idle_ms >= 0
    assert abs(eng.executor.verify.busy_ms - stats.verifier_busy_ms) < 1e-6
    assert abs(eng.executor.verify.idle_ms - stats.verifier_idle_ms) < 1e-6


@pytest.mark.slow
@pytest.mark.parametrize("strategy", ["cosine", "pipeinfer"])
def test_pipelined_lossless_ssm_target(models, strategy):
    """SSM verifiers take the chain-only tree path; the decoupled executor
    must stay lossless there too."""
    scfg, sparams = models["ssm"]
    eng = _engine(models, "ssm", strategy)
    for p, t in zip(_prompts(3, rng_seed=11), [0.0, 80.0, 400.0]):
        eng.submit(p, max_new_tokens=8, arrival_ms=t)
    eng.run()
    assert eng.pool.empty
    for r in eng.pool.completed:
        assert r.generated == _greedy_reference(scfg, sparams, r.prompt, 8), \
            strategy


# ------------------------------------------------- fast: determinism + ahead
def test_executor_event_order_deterministic(models):
    def trace(seed):
        eng = _engine(models, "attn", "cosine", seed=seed)
        for p, t in zip(_prompts(3, rng_seed=7), [0.0, 90.0, 300.0]):
            eng.submit(p, max_new_tokens=6, arrival_ms=t)
        eng.run()
        gen = {tuple(r.prompt.tolist()): list(r.generated)
               for r in eng.pool.completed}
        return eng.executor.log.trace(), gen

    t1, g1 = trace(0)
    t2, g2 = trace(0)
    assert t1 == t2 and g1 == g2
    assert len(t1) > 0
    kinds = {(ev[2], ev[3]) for ev in t1}
    # drafting happens on per-node stage clocks (draft0, draft1, ...)
    assert any(stage.startswith("draft") and kind == "draft_start"
               for stage, kind in kinds)
    assert ("verify", "verify_start") in kinds


def test_draft_ahead_invalidation_on_rejection(models):
    """Random-init drafters disagree with the target almost always, so
    every optimistic draft-ahead must be invalidated and re-drafted from
    the committed state — without breaking losslessness."""
    tcfg, tparams = models["attn"]
    eng = _engine(models, "attn", "cosine")
    p = _prompts(1, rng_seed=19)[0]
    eng.submit(p, max_new_tokens=10)
    stats = eng.run()
    r = eng.pool.completed[0]
    assert r.generated == _greedy_reference(tcfg, tparams, p, 10)
    assert eng.executor.n_invalidated > 0
    assert stats.n_invalidated == eng.executor.n_invalidated
    inval = [ev for ev in eng.executor.log.events if ev.kind == "invalidate"]
    redrafts = [ev for ev in eng.executor.log.events
                if ev.kind == "redraft_start"]
    assert inval and redrafts
    # redrafting begins only once the verification outcome is known
    for ev in redrafts:
        commits_before = [e for e in eng.executor.log.events
                          if e.kind == "verify_end" and e.t_ms <= ev.t_ms + 1e-9]
        assert commits_before


def test_draft_ahead_survives_with_perfect_drafter(models):
    """If the drafter is the target itself, every assumed token is
    accepted and the correction equals the ahead-draft's next token: the
    in-flight draft survives (shifted), nothing is invalidated, and the
    steady-state iteration period collapses to the verification time —
    overlap emerging from the event timeline, not from a formula."""
    tcfg, tparams = models["attn"]
    eng = _engine(models, "attn", "pipeinfer",
                  drafters=[(tcfg, tparams, "self")])
    p = _prompts(1, rng_seed=23)[0]
    eng.submit(p, max_new_tokens=12)
    stats = eng.run()
    r = eng.pool.completed[0]
    assert r.generated == _greedy_reference(tcfg, tparams, p, 12)
    assert eng.executor.n_invalidated == 0
    assert eng.executor.n_survived > 0
    # steady state (pipe filled, draft hidden behind verify): period == t_llm
    for rec in stats.records[1:]:
        assert rec.verify_idle_ms < 1e-6
        assert abs(rec.t_iter_ms - rec.verify_ms) < 1e-6


# --------------------------------------------------- fast: emergent overlap
def test_pipelined_overlap_beats_coupled_idle(models):
    """The acceptance criterion's overlap direction: measured verifier
    idle fraction of the decoupled executor is below the coupled
    baseline's on the same workload (where the verifier provably waits
    out every draft+comm phase)."""
    def idle_frac(strategy):
        eng = _engine(models, "attn", strategy, seed=1)
        for p in _prompts(4, rng_seed=29):
            eng.submit(p, max_new_tokens=8)
        stats = eng.run()
        return (stats.verifier_idle_ms
                / max(stats.verifier_idle_ms + stats.verifier_busy_ms, 1e-9))

    assert idle_frac("cosine") < idle_frac("specinfer")


def test_pipelined_latency_close_to_analytic_formula(models):
    """Measured pipelined latency may exceed the optimistic
    max(draft+comm, verify) accounting only by the invalidation redrafts
    (plus pipe fill) — it must stay within a small factor even with
    worst-case (random-drafter) rejection rates."""
    eng = _engine(models, "attn", "cosine", seed=1)
    for p in _prompts(4, rng_seed=31):
        eng.submit(p, max_new_tokens=8)
    stats = eng.run()
    formula = sum(max(rec.draft_ms + eng.lat.comm_ms, rec.verify_ms)
                  for rec in stats.records) + stats.prefill_busy_ms
    assert stats.sim_ms <= formula * 1.30
    # and it can never beat the coupled accounting's own stage sum
    assert stats.sim_ms >= max(rec.verify_ms for rec in stats.records)


@pytest.mark.parametrize("strategy", ["cosine", "pipeinfer"])
def test_pipelined_ttft_includes_prefill(models, strategy):
    """Cold-start honesty: the prompt forward is a verify-stage job, so
    no pipelined request can see its first token before its prefill has
    been paid (the seed charged zero time for prefill)."""
    eng = _engine(models, "attn", strategy)
    p = _prompts(1, rng_seed=41, length=24)[0]
    eng.submit(p, max_new_tokens=4)
    stats = eng.run()
    r = eng.pool.completed[0]
    t_pf = eng.lat.t_prefill(len(p))
    assert r.first_token_ms >= t_pf
    kinds = [ev.kind for ev in eng.executor.log.events]
    assert "prefill_start" in kinds and "prefill_end" in kinds
    # prefill time lands in the records and in the verify-stage busy sum
    assert stats.prefill_busy_ms >= t_pf - 1e-9
    assert abs(eng.executor.verify.busy_ms - stats.verifier_busy_ms) < 1e-6
    # a prefill event never starts before the request's arrival
    starts = [ev for ev in eng.executor.log.events
              if ev.kind == "prefill_start"]
    assert all(ev.t_ms >= 0.0 for ev in starts)


def test_bursty_arrivals_queue_prefills_on_verify_stage(models):
    """Two simultaneous cold arrivals: their prefills serialize on the
    verification server, so the second request's first draft cannot
    start before both prompt forwards are done."""
    eng = _engine(models, "attn", "pipeinfer")
    for p in _prompts(2, rng_seed=43, length=16):
        eng.submit(p, max_new_tokens=4)
    eng.run()
    evs = eng.executor.log.events
    pf = [(ev.t_ms, ev.kind) for ev in evs if ev.kind.startswith("prefill")]
    assert len([k for _, k in pf if k == "prefill_start"]) == 2
    # serialized: second prefill starts at/after the first one ends
    ends = sorted(t for t, k in pf if k == "prefill_end")
    starts = sorted(t for t, k in pf if k == "prefill_start")
    assert starts[1] >= ends[0] - 1e-9
    # drafting that includes both requests begins after the last prefill
    d0 = min(ev.t_ms for ev in evs if ev.kind == "draft_start"
             and len(ev.rids) == 2)
    assert d0 >= ends[1] - 1e-9


def test_single_token_prompt_keeps_one_behind_invariant(models):
    """A one-token prompt means the drafters prefill an *empty* context
    (bare slot); the one-behind invariant must hold from the first
    iteration — historically this re-fed the only token twice."""
    tcfg, tparams = models["attn"]
    for strategy in ("cosine", "vanilla"):
        eng = _engine(models, "attn", strategy)
        eng.submit([7], max_new_tokens=6)
        eng.run()
        r = eng.pool.completed[0]
        assert r.generated == _greedy_reference(tcfg, tparams, [7], 6), \
            strategy
