"""Per-arch smoke tests (deliverable f): reduced variant of each assigned
architecture runs one forward + one train step on CPU; output shapes and
NaN-freeness asserted."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import model as M
from repro.optim.optimizers import adamw, apply_updates

# compiles every assigned architecture (minutes of XLA time) — nightly tier
pytestmark = pytest.mark.slow

ARCH_IDS = sorted(ARCHS)


def _frontend(cfg, batch, key):
    if cfg.n_frontend_tokens:
        return jax.random.normal(key, (batch, cfg.n_frontend_tokens,
                                       cfg.d_model)) * 0.1
    return None


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nan(arch):
    cfg = ARCHS[arch].reduced().with_overrides(dtype="float32")
    assert cfg.d_model <= 512 and (cfg.moe is None or cfg.moe.n_routed <= 4)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    fe = _frontend(cfg, 2, jax.random.PRNGKey(2))
    logits, _, aux = M.apply(params, cfg, toks, frontend=fe)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = ARCHS[arch].reduced().with_overrides(dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw(1e-3)
    opt_state = opt.init(params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    fe = _frontend(cfg, 2, jax.random.PRNGKey(2))

    def loss_fn(p):
        loss, _ = M.lm_loss(p, cfg, toks, frontend=fe, remat=False)
        return loss

    loss0, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss0))
    gnorm = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0
    updates, opt_state = opt.update(grads, opt_state, params)
    params = apply_updates(params, updates)
    loss1 = loss_fn(params)
    assert np.isfinite(float(loss1))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_with_cache_matches_full(arch):
    cfg = ARCHS[arch].reduced().with_overrides(dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    fe = _frontend(cfg, 2, jax.random.PRNGKey(2))
    full, _, _ = M.apply(params, cfg, toks, frontend=fe)
    cache = M.init_cache(cfg, 2, 32, dtype=jnp.float32)
    lp, cache, _ = M.prefill(params, cfg, toks[:, :8], cache, frontend=fe)
    np.testing.assert_allclose(np.asarray(lp[:, :8, :cfg.vocab]),
                               np.asarray(full[:, :8, :cfg.vocab]),
                               rtol=2e-3, atol=2e-3)
    for t in range(8, 12):
        ls, cache, _ = M.decode_step(params, cfg, toks[:, t:t + 1], cache)
        np.testing.assert_allclose(np.asarray(ls[:, 0, :cfg.vocab]),
                                   np.asarray(full[:, t, :cfg.vocab]),
                                   rtol=5e-3, atol=5e-3)
