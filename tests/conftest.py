import os
import sys

# smoke tests / benches must see ONE device (the dry-run sets 512 itself,
# in a separate process)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))  # _hypothesis_fallback

import jax
import numpy as np
import pytest

from repro.configs.drafters import tiny_drafter, tiny_target
from repro.data.synthetic import DOMAINS, SyntheticCorpus


@pytest.fixture(scope="session")
def trained_tiny():
    """Session fixture: a trained tiny target + 3 domain drafters (V=64,
    sharp domains so acceptance is meaningfully > 0)."""
    from repro.launch.train import train_model
    V = 64
    corpus = SyntheticCorpus(V, seed=0)
    tcfg = tiny_target(V)
    tparams, _ = train_model(tcfg, corpus, None, steps=80, batch=8, seq=48,
                             verbose=False)
    dcfg = tiny_drafter(V)
    drafters = []
    for i, dom in enumerate(DOMAINS[:3]):
        dp, _ = train_model(dcfg, corpus, dom, steps=50, batch=8, seq=48,
                            seed=i + 1, verbose=False)
        drafters.append((dcfg, dp, dom))
    return dict(corpus=corpus, target=(tcfg, tparams), drafters=drafters,
                vocab=V)
