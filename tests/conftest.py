import os
import sys

# smoke tests / benches must see ONE device (the dry-run sets 512 itself,
# in a separate process)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))  # _hypothesis_fallback
# repo root: tests exercise benchmarks.* helpers (completion_stats)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import pytest

from repro.config import ModelConfig, SSMConfig
from repro.configs.drafters import tiny_drafter, tiny_target
from repro.data.synthetic import DOMAINS, SyntheticCorpus

# shared by test_runner_slots / test_pipeline: identical configs and
# max_len keep the module-level jit caches warm across both modules
TINY_MAX_LEN = 96


def tiny_model_cfg(kind: str) -> ModelConfig:
    """Random-init-able tiny config: 'attn', 'ssm' or 'hybrid'."""
    common = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                  head_dim=16, d_ff=128, vocab=50, tie_embeddings=True,
                  dtype="float32")
    if kind == "attn":
        return ModelConfig(name="tiny-attn", family="dense", **common)
    if kind == "ssm":
        return ModelConfig(name="tiny-ssm", family="ssm",
                           ssm=SSMConfig(d_state=16, head_dim=16,
                                         chunk_size=16), **common)
    return ModelConfig(name="tiny-hybrid", family="hybrid",
                       hybrid_attn_period=2, hybrid_attn_offset=1,
                       ssm=SSMConfig(d_state=16, head_dim=16, chunk_size=16),
                       **common)


@pytest.fixture(scope="module", autouse=True)
def _bound_compiled_executables():
    """Drop compiled-executable caches at module boundaries.

    The full fast suite jit-compiles hundreds of tiny programs; letting
    the executables accumulate for the whole run can segfault XLA:CPU's
    JIT deep into the suite (observed in `model.apply`'s scan compile
    during test_subbatch, identically with and without the newest test
    modules). Clearing per module bounds that state; each module
    recompiles its handful of tiny programs in seconds.
    """
    yield
    import jax

    jax.clear_caches()


@pytest.fixture(scope="session")
def trained_tiny():
    """Session fixture: a trained tiny target + 3 domain drafters (V=64,
    sharp domains so acceptance is meaningfully > 0)."""
    from repro.launch.train import train_model
    V = 64
    corpus = SyntheticCorpus(V, seed=0)
    tcfg = tiny_target(V)
    tparams, _ = train_model(tcfg, corpus, None, steps=80, batch=8, seq=48,
                             verbose=False)
    dcfg = tiny_drafter(V)
    drafters = []
    for i, dom in enumerate(DOMAINS[:3]):
        dp, _ = train_model(dcfg, corpus, dom, steps=50, batch=8, seq=48,
                            seed=i + 1, verbose=False)
        drafters.append((dcfg, dp, dom))
    return dict(corpus=corpus, target=(tcfg, tparams), drafters=drafters,
                vocab=V)
