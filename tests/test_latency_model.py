"""Latency/cost model properties + calibration round-trip."""
import numpy as np

from repro.core.latency_model import HW, LatencyModel


def test_pipelined_never_slower_than_coupled():
    lat = LatencyModel()
    for b in (1, 4, 16):
        for l in (64, 1024, 8192):
            for g in (1, 5, 12):
                assert lat.iteration_pipelined(b, l, g, b * g) <= \
                    lat.iteration_coupled(b, l, g, b * g)


def test_t_ssm_linear_in_gamma():
    lat = LatencyModel()
    t1 = lat.t_ssm(1, 256, 1)
    t4 = lat.t_ssm(1, 256, 4)
    assert abs(t4 - 4 * t1) < 1e-9


def test_verification_cheaper_than_ar_per_token():
    """The paper's premise: verifying Gamma tokens in one forward beats
    Gamma AR forwards."""
    lat = LatencyModel()
    gamma = 5
    t_verify = lat.t_llm(1, 256, gamma)
    t_ar = gamma * lat.t_llm(1, 256, 1)
    assert t_verify < t_ar


def test_iteration_coupled_charges_prefill():
    """The coupled baselines pay cold-start prompt forwards (TTFT parity
    with the pipelined strategies, which schedule prefill jobs on the
    verify stage)."""
    lat = LatencyModel()
    base = lat.iteration_coupled(2, 128, 4, 8)
    pf = lat.t_prefill(128)
    assert abs(lat.iteration_coupled(2, 128, 4, 8, prefill_ms=pf)
               - (base + pf)) < 1e-9
    assert pf > 0


def test_per_node_primitives_match_homogeneous_model():
    """A default (speed=1, no jitter) profile decomposes t_ssm exactly:
    gamma * (step + sync) == t_ssm(b, l, gamma, n)."""
    from repro.core.latency_model import DrafterProfile
    lat = LatencyModel()
    prof = DrafterProfile()
    for b, l, g, n in [(1, 64, 3, 1), (4, 512, 5, 3), (8, 2048, 2, 2)]:
        per_node = g * (lat.ssm_step_node(b, l, prof) + lat.sync_ms(n))
        assert abs(per_node - lat.t_ssm(b, l, g, n)) < 1e-9
    # heterogeneity scales the step, comm override falls back correctly
    slow = DrafterProfile(speed=2.0, comm_ms=3.5)
    assert abs(lat.ssm_step_node(1, 64, slow)
               - 2.0 * lat.ssm_step_node(1, 64, prof)) < 1e-12
    assert lat.node_comm_ms(slow) == 3.5
    assert lat.node_comm_ms(prof) == lat.comm_ms


def test_cost_model_charges_drafters():
    lat = LatencyModel()
    c0 = lat.cost_per_ms(0)
    c4 = lat.cost_per_ms(4)
    assert c4 > c0
    assert abs((c4 - c0) * 3600.0 * 1000.0 - 4 * HW["2080Ti"]["rent"]) < 1e-9


def test_fit_recovers_coefficients():
    lat = LatencyModel()
    rng = np.random.default_rng(0)
    samples = []
    for _ in range(40):
        b = int(rng.integers(1, 16))
        l = int(rng.integers(64, 4096))
        g = int(rng.integers(1, 12))
        samples.append((b, l, g, lat.t_ssm(b, l, g)))
    fresh = LatencyModel(ssm_step_ms=1.0, ssm_ctx_ms_per_ktok=1.0,
                         ssm_batch_ms=1.0)
    fresh.fit_ssm(samples)
    assert abs(fresh.ssm_step_ms - lat.ssm_step_ms) < 1e-6
    assert abs(fresh.ssm_ctx_ms_per_ktok - lat.ssm_ctx_ms_per_ktok) < 1e-6
