"""Substrate tests: optimizers, checkpointing, synthetic data."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import load_checkpoint, save_checkpoint
from repro.configs.drafters import tiny_drafter
from repro.data.synthetic import DOMAINS, SyntheticCorpus
from repro.models import model as M
from repro.optim.optimizers import (adafactor, adamw, apply_updates,
                                    get_optimizer, sgd)


@pytest.mark.parametrize("name", ["adamw", "sgd", "adafactor"])
def test_optimizer_reduces_quadratic(name):
    opt = get_optimizer(name, lr=0.1)
    params = {"w": jnp.array([3.0, -2.0]), "b": jnp.ones((4, 16))}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)

    l0 = float(loss(params))
    for _ in range(30):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    assert float(loss(params)) < 0.5 * l0


def test_adafactor_state_is_factored():
    opt = adafactor(0.01)
    params = {"big": jnp.zeros((64, 32)), "vec": jnp.zeros((7,))}
    st = opt.init(params)
    assert set(st["s"]["big"].keys()) == {"vr", "vc"}
    assert st["s"]["big"]["vr"].shape == (64,)
    assert st["s"]["big"]["vc"].shape == (32,)
    assert set(st["s"]["vec"].keys()) == {"v"}


def test_optimizer_on_model_params():
    cfg = tiny_drafter(32)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    for name in ("adamw", "adafactor", "sgd"):
        opt = get_optimizer(name, 1e-3)
        state = opt.init(params)
        g = jax.tree.map(jnp.ones_like, params)
        upd, state = opt.update(g, state, params)
        newp = apply_updates(params, upd)
        assert jax.tree.structure(newp) == jax.tree.structure(params)


def test_checkpoint_roundtrip(tmp_path):
    cfg = tiny_drafter(32)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    path = os.path.join(tmp_path, "ck.msgpack")
    save_checkpoint(path, params, meta={"step": 7})
    restored, meta = load_checkpoint(path)
    assert meta["step"] == 7
    assert jax.tree.structure(restored) == jax.tree.structure(params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_corpus_domains_are_distinct():
    c = SyntheticCorpus(64, seed=0)
    # a bigram model trained on domain A should be more "surprised" by B
    def bigram_counts(rows):
        m = np.ones((64, 64))
        for row in rows:
            for a, b in zip(row[:-1], row[1:]):
                m[a, b] += 1
        return m / m.sum(1, keepdims=True)

    rows_a = c.batch("piqa", 20, 64)
    rows_b = c.batch("medqa", 20, 64)
    pa = bigram_counts(rows_a)

    def nll(rows, p):
        return -np.mean([np.log(p[a, b]) for row in rows
                         for a, b in zip(row[:-1], row[1:])])

    assert nll(rows_b, pa) > nll(rows_a, pa) + 0.3


def test_corpus_prompts_cover_domains():
    c = SyntheticCorpus(64, seed=0)
    prompts = c.prompts(10, 8, seed=1)
    assert len(prompts) == 10
    doms = {d for _, d in prompts}
    assert doms == set(DOMAINS)
