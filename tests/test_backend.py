"""Backend contract (serving/backend.py, DESIGN.md §2.7).

SimulatedBackend: the engine speaking the ExecutionBackend interface
must be byte-identical same-seed — committed tokens, ServeStats and the
trace export are deterministic functions of (workload, seed) with no
dependence on how the backend instance was constructed. Burst admission
(`batched_prefill`) coalesces cold prompt forwards into one masked
slot_extend write per model with identical tokens.

AsyncJaxBackend: the wall-clock loop is lossless (greedy-exact against
the AR reference, attention + SSM targets, admission/preemption churn
included) and demonstrates *real* overlap — measured verifier idle with
draft-ahead below the serial coupled loop's on the same workload.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import TINY_MAX_LEN as MAX_LEN, tiny_model_cfg as _tiny
from repro.config import CoSineConfig, ModelConfig
from repro.models import model as M
from repro.obs.export import build_trace
from repro.serving.backend import (AsyncJaxBackend, SimulatedBackend,
                                   make_backend)
from repro.serving.engine import SpeculativeEngine


@pytest.fixture(scope="module")
def models():
    tcfg = _tiny("attn")
    scfg = _tiny("ssm")
    key = jax.random.PRNGKey(0)
    tparams = M.init_params(key, tcfg)
    sparams = M.init_params(key, scfg)
    dcfg = ModelConfig(name="tiny-draft", family="dense", n_layers=1,
                       d_model=48, n_heads=2, n_kv_heads=2, head_dim=16,
                       d_ff=96, vocab=50, tie_embeddings=True,
                       dtype="float32")
    drafters = [(dcfg, M.init_params(jax.random.PRNGKey(i + 1), dcfg), f"d{i}")
                for i in range(2)]
    return {"attn": (tcfg, tparams), "ssm": (scfg, sparams),
            "drafters": drafters}


def _greedy_reference(cfg, params, prompt, n):
    cache = M.init_cache(cfg, 1, MAX_LEN, dtype=jnp.float32)
    lg, cache, _ = M.prefill(params, cfg, jnp.asarray(prompt)[None, :], cache)
    last = np.asarray(lg[0, -1, :cfg.vocab])
    out = []
    for _ in range(n):
        t = int(np.argmax(last))
        out.append(t)
        lg, cache, _ = M.decode_step(params, cfg, jnp.asarray([[t]]), cache)
        last = np.asarray(lg[0, 0, :cfg.vocab])
    return out


def _engine(models, family, strategy, seed=0, backend=None, **cos_kw):
    kw = dict(n_drafters=2, draft_len=4, drafters_per_request=2,
              tree_width=2)
    kw.update(cos_kw)
    cos = CoSineConfig(**kw)
    return SpeculativeEngine(models[family], models["drafters"], cos,
                             strategy=strategy, max_len=MAX_LEN, seed=seed,
                             backend=backend)


def _prompts(n, rng_seed=3, length=8):
    rng = np.random.default_rng(rng_seed)
    return [rng.integers(1, 50, length).tolist() for _ in range(n)]


def _run(eng, prompts, max_new=10, arrivals=None):
    arrivals = arrivals or [0.0] * len(prompts)
    reqs = [eng.submit(p, max_new_tokens=max_new, arrival_ms=t)
            for p, t in zip(prompts, arrivals)]
    stats = eng.run()
    eng.backend.shutdown()
    return reqs, stats


def _stats_key(stats):
    """The ServeStats surface the fig7 bench reports, exactly."""
    return (stats.total_committed, stats.total_drafted, stats.draft_calls,
            stats.sim_ms, stats.verifier_busy_ms, stats.verifier_idle_ms,
            stats.n_invalidated,
            [(r.t_start_ms, r.t_iter_ms, r.batch, r.big_gamma, r.committed,
              r.verify_start_ms, r.verify_ms, r.verify_idle_ms,
              r.prefill_ms) for r in stats.records])


def _trace_key(tracer):
    t = build_trace(tracer)
    return [(e.get("name"), e.get("ph"), e.get("ts"), e.get("dur"),
             e.get("tid")) for e in t["traceEvents"]]


# ----------------------------------------------------- simulated: identity
def test_make_backend_resolution(models):
    t, ds = models["attn"], models["drafters"]
    assert isinstance(make_backend(None, t, ds, MAX_LEN), SimulatedBackend)
    assert isinstance(make_backend("sim", t, ds, MAX_LEN), SimulatedBackend)
    b = make_backend("async", t, ds, MAX_LEN)
    assert isinstance(b, AsyncJaxBackend)
    b.shutdown()
    assert make_backend(b, t, ds, MAX_LEN) is b
    with pytest.raises(ValueError):
        make_backend("gpu", t, ds, MAX_LEN)


@pytest.mark.parametrize("strategy", ["cosine", "pipeinfer", "vanilla", "ar"])
def test_sim_backend_byte_identical_same_seed(models, strategy):
    """The fig7 identity contract: tokens, ServeStats records and the
    trace export are pure functions of (workload, seed) through the
    backend interface — two constructions can never diverge."""
    outs = []
    for spec in (None, "sim"):
        eng = _engine(models, "attn", strategy, backend=spec)
        reqs, stats = _run(eng, _prompts(3), max_new=8,
                           arrivals=[0.0, 40.0, 200.0])
        outs.append(([list(map(int, r.generated)) for r in reqs],
                     _stats_key(stats), _trace_key(eng.tracer)))
    assert outs[0][0] == outs[1][0]
    assert outs[0][1] == outs[1][1]
    assert outs[0][2] == outs[1][2]


@pytest.mark.parametrize("family", ["attn", "ssm"])
def test_burst_prefill_identical_tokens_fewer_writes(models, family):
    """Burst admission: with `batched_prefill` a burst of cold arrivals
    shares one masked slot_extend write per model; tokens are identical
    and the target issues strictly fewer prefill writes."""
    results = {}
    for batched in (False, True):
        eng = _engine(models, family, "cosine", batched_prefill=batched)
        reqs, _ = _run(eng, _prompts(4, rng_seed=5), max_new=8)
        results[batched] = ([list(map(int, r.generated)) for r in reqs],
                            eng.target.n_prefill_writes)
    assert results[True][0] == results[False][0]
    assert results[True][1] < results[False][1]


def test_burst_prefill_single_cold_falls_back(models):
    """A lone cold request takes the per-request path even with
    `batched_prefill` on — no shape churn for the common case."""
    eng = _engine(models, "attn", "cosine", batched_prefill=True)
    reqs, _ = _run(eng, _prompts(1), max_new=6)
    (tcfg, tparams) = models["attn"]
    assert list(map(int, reqs[0].generated)) == _greedy_reference(
        tcfg, tparams, reqs[0].prompt, 6)


# -------------------------------------------------------- async: lossless
@pytest.mark.parametrize("family", ["attn", "ssm"])
@pytest.mark.parametrize("strategy", ["cosine", "pipeinfer"])
def test_async_backend_lossless(models, family, strategy):
    cfg, params = models[family]
    prompts = _prompts(3)
    eng = _engine(models, family, strategy, backend="async")
    reqs, stats = _run(eng, prompts, max_new=10)
    for r, p in zip(reqs, prompts):
        assert r.done
        assert list(map(int, r.generated)) == _greedy_reference(
            cfg, params, p, 10), strategy
    # wall-clock records are measured, not booked
    assert stats.records and all(r.verify_ms > 0 for r in stats.records)
    assert all(r.t_iter_ms >= 0 for r in stats.records)


def test_async_backend_lossless_under_churn(models):
    """Admission churn (tight batch, priorities, preemption + shed
    pressure) on the wall-clock loop: every request that completes is
    still greedy-exact."""
    cfg, params = models["attn"]
    cos = CoSineConfig(n_drafters=2, draft_len=4, drafters_per_request=2,
                       tree_width=2, enable_admission=True, max_batch=2,
                       admit_queue_cap=2, preempt_priority=True,
                       default_slo_ms=1e6)
    eng = SpeculativeEngine(models["attn"], models["drafters"], cos,
                            strategy="cosine", max_len=MAX_LEN, seed=0,
                            backend="async")
    prompts = _prompts(5, rng_seed=9)
    reqs = [eng.submit(p, max_new_tokens=8, arrival_ms=0.0,
                       priority=i % 3) for i, p in enumerate(prompts)]
    stats = eng.run()
    eng.backend.shutdown()
    done = [(r, p) for r, p in zip(reqs, prompts) if r.done]
    assert done, "churn shed everything — config too tight"
    for r, p in done:
        assert list(map(int, r.generated)) == _greedy_reference(
            cfg, params, p, 8)
    assert stats.total_committed >= sum(len(r.generated) for r, _ in done)


def test_async_preemption_readmit_lossless(models):
    """A preempted request re-prefills prompt+generated through the
    async burst-prefill queue; its final stream must still be exact."""
    cfg, params = models["attn"]
    cos = CoSineConfig(n_drafters=2, draft_len=4, drafters_per_request=2,
                       tree_width=2, enable_admission=True, max_batch=1,
                       preempt_priority=True, default_slo_ms=1e6)
    eng = SpeculativeEngine(models["attn"], models["drafters"], cos,
                            strategy="cosine", max_len=MAX_LEN, seed=0,
                            backend="async")
    prompts = _prompts(3, rng_seed=11)
    # low-priority first, then high-priority arrivals that displace it
    reqs = [eng.submit(prompts[0], max_new_tokens=10, priority=2),
            eng.submit(prompts[1], max_new_tokens=10, priority=0),
            eng.submit(prompts[2], max_new_tokens=10, priority=0)]
    eng.run()
    eng.backend.shutdown()
    for r, p in zip(reqs, prompts):
        if r.done:
            assert list(map(int, r.generated)) == _greedy_reference(
                cfg, params, p, 10)


# --------------------------------------------------------- async: overlap
@pytest.mark.slow
def test_async_overlap_beats_serial_idle(models):
    """The acceptance criterion, measured for real: on a draft-bound
    workload the draft-ahead wall-clock loop keeps the verification
    server busier than the serial coupled loop (draft, then verify,
    alternating on the same thread).

    The target serves as its own drafter so every draft-ahead survives
    (acceptance ~= 1): the measurement isolates the loop discipline
    from drafter quality — with weak drafters most speculations are
    redrafted and the overlap win is eaten by the redraft cost, which
    is speculation physics, not a loop defect. The tiny test models
    are dispatch-bound — one op does not saturate the host's cores —
    which is the regime where concurrent drafting is free capacity
    instead of contention (the bench-fixture-sized target loses the
    margin to exactly that contention; DESIGN.md §2.7). Each strategy
    gets a warm-up run at the exact measured shapes so jit compiles
    never land inside a measured span, and measured reps alternate so
    host drift cancels out of the mean."""
    tcfg, tparams = models["attn"]
    perfect = [(tcfg, tparams, f"d{i}") for i in range(2)]

    def serve(strategy):
        cos = CoSineConfig(n_drafters=2, draft_len=8,
                           drafters_per_request=2, tree_width=2)
        eng = SpeculativeEngine(models["attn"], perfect, cos,
                                strategy=strategy, max_len=MAX_LEN,
                                seed=0, backend="async")
        _, stats = _run(eng, _prompts(8, rng_seed=13), max_new=32)
        busy, idle = stats.verifier_busy_ms, stats.verifier_idle_ms
        return idle / max(busy + idle, 1e-9), stats

    serve("vanilla")                   # warm-up: compile at these shapes
    serve("pipeinfer")
    serial_reps, over_reps = [], []
    for _ in range(3):
        s, _ = serve("vanilla")        # overlap=False: draft blocks verify
        o, stats = serve("pipeinfer")
        serial_reps.append(s)
        over_reps.append(o)
    serial = float(np.mean(serial_reps))
    overlapped = float(np.mean(over_reps))
    assert overlapped < serial, (over_reps, serial_reps)

    # structural check, immune to wall noise: most cohorts began
    # drafting before the previous verification finished
    rs = stats.records
    hits = sum(1 for prev, nxt in zip(rs, rs[1:])
               if nxt.draft_start_ms < prev.verify_start_ms + prev.verify_ms)
    assert hits / (len(rs) - 1) > 0.5, (hits, len(rs))


def test_async_wallclock_monotone_and_streaming(models):
    """Wall-clock sanity: commits arrive in nondecreasing wall time, the
    on_commit streaming hook sees every committed token once as it
    commits, and the final commit observes req.done already set (a
    streaming consumer keyed on it must terminate — the asyncio
    front-end in examples/serve_online.py hangs otherwise)."""
    eng = _engine(models, "attn", "cosine", backend="async")
    seen = {}
    times = []
    done_at = {}

    def on_commit(req, toks, now_ms):
        seen.setdefault(req.rid, []).extend(toks)
        times.append(now_ms)
        done_at[req.rid] = req.done

    eng.on_commit = on_commit
    reqs, _ = _run(eng, _prompts(2), max_new=8)
    assert times == sorted(times)
    for r in reqs:
        assert seen[r.rid] == list(r.generated)
        assert done_at[r.rid] is True
