"""Deterministic fallback for the property-test surface of `hypothesis`.

`hypothesis` is a declared test dependency (pyproject / requirements),
but its absence must never hard-fail collection of the tier-1 suite. The
four property-test modules import it with a try/except falling back to
this shim, which replays each `@given` test over a fixed-seed stream of
pseudo-random examples drawn from minimal strategy emulations — degraded
(no shrinking, no edge-case bias) but still exercising the properties.

Only the strategy combinators the suite actually uses are implemented:
integers, floats, booleans, sampled_from, tuples, lists.
"""
from __future__ import annotations

import random
from types import SimpleNamespace

FALLBACK_SEED = 0xC0541
FALLBACK_MAX_EXAMPLES = 25


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


def _integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def _floats(min_value, max_value, **_kw):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def _booleans():
    return _Strategy(lambda rng: rng.random() < 0.5)


def _sampled_from(seq):
    seq = list(seq)
    return _Strategy(lambda rng: seq[rng.randrange(len(seq))])


def _tuples(*strategies):
    return _Strategy(lambda rng: tuple(s.example(rng) for s in strategies))


def _lists(elements, min_size=0, max_size=None):
    hi = max_size if max_size is not None else min_size + 10

    def draw(rng):
        n = rng.randint(min_size, hi)
        return [elements.example(rng) for _ in range(n)]
    return _Strategy(draw)


st = SimpleNamespace(integers=_integers, floats=_floats, booleans=_booleans,
                     sampled_from=_sampled_from, tuples=_tuples, lists=_lists)


def settings(max_examples=FALLBACK_MAX_EXAMPLES, **_kw):
    """Records max_examples for @given; all other knobs are no-ops."""
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*strategies):
    """Replays the test over deterministic pseudo-random examples.

    The wrapper takes no parameters (the real @given also strips them), so
    pytest does not mistake strategy arguments for fixtures.
    """
    def deco(fn):
        n = min(getattr(fn, "_fallback_max_examples", FALLBACK_MAX_EXAMPLES),
                FALLBACK_MAX_EXAMPLES)

        def wrapper():
            rng = random.Random(FALLBACK_SEED)
            for _ in range(n):
                fn(*(s.example(rng) for s in strategies))
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco
