"""Regression tests for the §Perf levers: parallel-partial decode path,
int8 KV quantization, head-aligned sharding rules, and the loop-corrected
HLO collective parser."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo import collective_bytes_corrected, parse_computations
from repro.configs import ARCHS
from repro.models import model as M

# exercises decode paths across the full arch matrix (compile-heavy) — nightly tier
pytestmark = pytest.mark.slow
from repro.models.attention import (attend_partial, attend_partial_parallel,
                                    make_kv_cache, write_kv, dequantize_cache)


def test_parallel_partials_match_scan():
    B, T, H, G, D, S = 2, 4, 2, 3, 16, 50
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, T, H, G, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    qp = jnp.full((B, T), 40, jnp.int32)
    kp = jnp.broadcast_to(jnp.arange(S), (B, S)).astype(jnp.int32)
    kp = jnp.where(kp < 45, kp, -1)
    a = attend_partial(q, k, v, qp, kp, scale=0.2, block=16)
    b = attend_partial_parallel(q, k, v, qp, kp, scale=0.2, block=16)
    # partials may differ (different m normalizers) but finalized outputs
    # must match; compare normalized
    fa = a[2] / jnp.where(a[1] == 0, 1, a[1])[..., None]
    fb = b[2] / jnp.where(b[1] == 0, 1, b[1])[..., None]
    np.testing.assert_allclose(np.asarray(fa), np.asarray(fb),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "deepseek-v3-671b"])
def test_decode_paths_equivalent(arch):
    base = ARCHS[arch].reduced().with_overrides(dtype="float32")
    params = M.init_params(jax.random.PRNGKey(1), base)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 10), 0, base.vocab)

    def decode(cfg):
        cache = M.init_cache(cfg, 2, 32, dtype=jnp.float32)
        _, cache, _ = M.prefill(params, cfg, toks[:, :6], cache)
        outs = []
        for t in range(6, 10):
            lg, cache, _ = M.decode_step(params, cfg, toks[:, t:t + 1], cache)
            outs.append(np.asarray(lg[:, 0, :cfg.vocab]))
        return np.stack(outs)

    ref = decode(base)
    par = decode(base.with_overrides(decode_attn="parallel", decode_block=8))
    np.testing.assert_allclose(par, ref, rtol=2e-4, atol=2e-4)


def test_int8_kv_quantization_roundtrip():
    c = make_kv_cache(2, 16, 2, 8, dtype=jnp.float32, quantized=True)
    assert c["k"].dtype == jnp.int8 and "k_scale" in c
    k_new = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 2, 8))
    v_new = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 2, 8))
    pos = jnp.broadcast_to(jnp.arange(3), (2, 3)).astype(jnp.int32)
    c = write_kv(c, k_new, v_new, pos)
    kd, vd = dequantize_cache(c)
    np.testing.assert_allclose(np.asarray(kd[:, :3], np.float32),
                               np.asarray(k_new), rtol=0.02, atol=0.02)
    np.testing.assert_allclose(np.asarray(vd[:, :3], np.float32),
                               np.asarray(v_new), rtol=0.02, atol=0.02)


def test_int8_kv_preserves_greedy_argmax():
    cfg = ARCHS["qwen2-0.5b"].reduced().with_overrides(dtype="float32")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0, cfg.vocab)

    def decode(c):
        cache = M.init_cache(c, 2, 32, dtype=jnp.float32)
        _, cache, _ = M.prefill(params, c, toks[:, :8], cache)
        lg, _, _ = M.decode_step(params, c, toks[:, 8:9], cache)
        return np.asarray(lg[:, 0, :c.vocab])

    ref = decode(cfg)
    q8 = decode(cfg.with_overrides(kv_dtype="int8"))
    assert np.abs(q8 - ref).max() < 0.5
    assert np.array_equal(np.argmax(q8, -1), np.argmax(ref, -1))


def test_head_aligned_sharding_replicates_misaligned_heads():
    from repro.distributed import sharding as sh

    class FM:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    cfg = ARCHS["qwen2-0.5b"]    # 14 heads, 2 kv heads: neither divides 16
    base = sh.param_specs(cfg, FM(), "train", head_align=False)
    align = sh.param_specs(cfg, FM(), "train", head_align=True)
    wq_base = base["stages"][0][0]["mixer"]["wq"]
    wq_align = align["stages"][0][0]["mixer"]["wq"]
    assert wq_base[2] == "model"       # baseline shards the flat dim
    assert wq_align[2] is None         # aligned rule replicates
    # MLP stays sharded either way
    assert align["stages"][0][0]["ffn"]["wg"][2] == "model"


HLO_SAMPLE = """HloModule test, is_scheduled=true

%body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %ag = f32[8]{0} all-gather(%x), channel_id=1, replica_groups=[2,2]<=[4], dimensions={0}
  ROOT %t = (s32[], f32[8]) tuple(%i, %ag)
}

%cond (p: (s32[], f32[8])) -> pred[] {
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8]) -> f32[8] {
  %w = (s32[], f32[8]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
  %ar = f32[16]{0} all-reduce(%y), channel_id=2, to_apply=%add
  ROOT %out = f32[8] get-tuple-element(%w), index=1
}
"""


def test_hlo_trip_count_correction():
    out, counts = collective_bytes_corrected(HLO_SAMPLE)
    assert counts["all-gather"] == 1 and counts["all-reduce"] == 1
    assert out["all-gather"] == 7 * 8 * 4      # trip-corrected
    assert out["all-reduce"] == 16 * 4         # entry-level, x1


def test_hlo_parser_finds_all_computations():
    comps = parse_computations(HLO_SAMPLE)
    entry = comps.pop("__entry__")[0]
    assert entry == "main"
    assert set(comps) == {"body", "cond", "main"}
