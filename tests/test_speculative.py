"""Speculative verification: greedy losslessness and the rejection-sampling
distribution-preservation property (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.speculative import (accept_counts_greedy, verify_greedy,
                                    verify_rejection)


def test_verify_greedy_all_accept():
    draft = jnp.array([[3, 1, 2]])
    tl = jnp.full((1, 3, 5), -10.0)
    tl = tl.at[0, 0, 3].set(0.).at[0, 1, 1].set(0.).at[0, 2, 2].set(0.)
    bonus = jnp.full((1, 5), -10.0).at[0, 4].set(0.)
    out, n = verify_greedy(draft, tl, bonus)
    assert int(n[0]) == 4
    assert out[0].tolist() == [3, 1, 2, 4]


def test_verify_greedy_reject_middle():
    draft = jnp.array([[3, 1, 2]])
    tl = jnp.full((1, 3, 5), -10.0)
    tl = tl.at[0, 0, 3].set(0.).at[0, 1, 0].set(0.).at[0, 2, 2].set(0.)
    bonus = jnp.full((1, 5), -10.0).at[0, 4].set(0.)
    out, n = verify_greedy(draft, tl, bonus)
    assert int(n[0]) == 2           # draft[0] accepted + correction
    assert out[0, :2].tolist() == [3, 0]


@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 6), st.integers(2, 8))
@settings(max_examples=20, deadline=None)
def test_greedy_acceptance_counts(seed, G, V):
    rng = np.random.default_rng(seed)
    draft = rng.integers(0, V, (3, G))
    tgt = rng.integers(0, V, (3, G))
    n = np.asarray(accept_counts_greedy(jnp.asarray(draft), jnp.asarray(tgt)))
    for b in range(3):
        expect = 0
        for i in range(G):
            if draft[b, i] == tgt[b, i]:
                expect += 1
            else:
                break
        assert n[b] == expect


@pytest.mark.parametrize("seed", [0, 1])
def test_rejection_sampling_preserves_target_distribution(seed):
    """Core speculative-decoding theorem: the marginal distribution of the
    FIRST output token equals the target distribution, regardless of the
    drafter. Empirical chi-square-ish check on a small vocab."""
    V, G = 5, 3
    key = jax.random.PRNGKey(seed)
    kq, kp, kr = jax.random.split(key, 3)
    q_logits = jax.random.normal(kq, (V,)) * 1.5
    p_logits = jax.random.normal(kp, (V,)) * 1.5
    q = jax.nn.softmax(q_logits)
    p = np.asarray(jax.nn.softmax(p_logits))

    N = 4000
    keys = jax.random.split(kr, N)

    def one(k):
        k1, k2 = jax.random.split(k)
        draft = jax.random.categorical(k1, jnp.broadcast_to(q_logits, (G, V)))
        draft_lp = jnp.log(jnp.broadcast_to(q, (1, G, V)))
        tl = jnp.broadcast_to(p_logits, (1, G, V))
        bonus = p_logits[None]
        out, n = verify_rejection(k2, draft[None], draft_lp, tl, bonus)
        return out[0, 0]

    first = np.asarray(jax.vmap(one)(keys))
    emp = np.bincount(first, minlength=V) / N
    # tolerance ~4 sigma of a multinomial proportion
    tol = 4 * np.sqrt(p * (1 - p) / N) + 0.01
    assert np.all(np.abs(emp - p) < tol), (emp, p)


def test_rejection_identical_models_accept_everything():
    V, G, B = 7, 4, 8
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (B, G, V))
    q = jax.nn.log_softmax(logits)
    draft = jnp.argmax(logits, -1)
    # drafter proposes argmax, and q == p pointwise -> p/q = 1 -> all accepted
    out, n = verify_rejection(jax.random.PRNGKey(1), draft, q, logits,
                              logits[:, -1])
    assert np.all(np.asarray(n) == G + 1)
