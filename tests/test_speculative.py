"""Speculative verification: greedy losslessness and the rejection-sampling
distribution-preservation property (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:     # declared dep; degrade so collection never hard-fails
    from _hypothesis_fallback import given, settings, st

from repro.core.speculative import (accept_counts_greedy, verify_greedy,
                                    verify_rejection)


def test_verify_greedy_all_accept():
    draft = jnp.array([[3, 1, 2]])
    tl = jnp.full((1, 3, 5), -10.0)
    tl = tl.at[0, 0, 3].set(0.).at[0, 1, 1].set(0.).at[0, 2, 2].set(0.)
    bonus = jnp.full((1, 5), -10.0).at[0, 4].set(0.)
    out, n = verify_greedy(draft, tl, bonus)
    assert int(n[0]) == 4
    assert out[0].tolist() == [3, 1, 2, 4]


def test_verify_greedy_reject_middle():
    draft = jnp.array([[3, 1, 2]])
    tl = jnp.full((1, 3, 5), -10.0)
    tl = tl.at[0, 0, 3].set(0.).at[0, 1, 0].set(0.).at[0, 2, 2].set(0.)
    bonus = jnp.full((1, 5), -10.0).at[0, 4].set(0.)
    out, n = verify_greedy(draft, tl, bonus)
    assert int(n[0]) == 2           # draft[0] accepted + correction
    assert out[0, :2].tolist() == [3, 0]


@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 6), st.integers(2, 8))
@settings(max_examples=20, deadline=None)
def test_greedy_acceptance_counts(seed, G, V):
    rng = np.random.default_rng(seed)
    draft = rng.integers(0, V, (3, G))
    tgt = rng.integers(0, V, (3, G))
    n = np.asarray(accept_counts_greedy(jnp.asarray(draft), jnp.asarray(tgt)))
    for b in range(3):
        expect = 0
        for i in range(G):
            if draft[b, i] == tgt[b, i]:
                expect += 1
            else:
                break
        assert n[b] == expect


@pytest.mark.parametrize("seed", [0, 1])
def test_rejection_sampling_preserves_target_distribution(seed):
    """Core speculative-decoding theorem: the marginal distribution of the
    FIRST output token equals the target distribution, regardless of the
    drafter. Empirical chi-square-ish check on a small vocab."""
    V, G = 5, 3
    key = jax.random.PRNGKey(seed)
    kq, kp, kr = jax.random.split(key, 3)
    q_logits = jax.random.normal(kq, (V,)) * 1.5
    p_logits = jax.random.normal(kp, (V,)) * 1.5
    q = jax.nn.softmax(q_logits)
    p = np.asarray(jax.nn.softmax(p_logits))

    N = 4000
    keys = jax.random.split(kr, N)

    def one(k):
        k1, k2 = jax.random.split(k)
        draft = jax.random.categorical(k1, jnp.broadcast_to(q_logits, (G, V)))
        draft_lp = jnp.log(jnp.broadcast_to(q, (1, G, V)))
        tl = jnp.broadcast_to(p_logits, (1, G, V))
        bonus = p_logits[None]
        out, n = verify_rejection(k2, draft[None], draft_lp, tl, bonus)
        return out[0, 0]

    first = np.asarray(jax.vmap(one)(keys))
    emp = np.bincount(first, minlength=V) / N
    # tolerance ~4 sigma of a multinomial proportion
    tol = 4 * np.sqrt(p * (1 - p) / N) + 0.01
    assert np.all(np.abs(emp - p) < tol), (emp, p)


def test_rejection_all_rejected_rows_emit_one_residual_token():
    """All-rejected edge case: when the target puts zero mass on every
    draft token, n_out == 1 and the single output token comes from the
    residual norm(max(p - q, 0)) — deterministically checkable with a
    one-hot residual."""
    V, G, B = 4, 3, 5
    # drafter is certain about token 0; target forbids it and wants token 2
    q_logits = jnp.array([0.0, -1e9, -1e9, -1e9])
    p_logits = jnp.array([-1e9, -1e9, 0.0, -1e9])
    draft = jnp.zeros((B, G), jnp.int32)
    draft_lp = jnp.broadcast_to(jax.nn.log_softmax(q_logits), (B, G, V))
    tl = jnp.broadcast_to(p_logits, (B, G, V))
    out, n = verify_rejection(jax.random.PRNGKey(3), draft, draft_lp, tl,
                              jnp.broadcast_to(p_logits, (B, V)))
    assert np.all(np.asarray(n) == 1)
    assert np.all(np.asarray(out)[:, 0] == 2)


def test_rejection_all_accepted_rows_take_bonus_from_target():
    """All-accepted edge case: q == p and drafts at the mode accept every
    position; the extra token is sampled from the target's post-draft
    (bonus) distribution — made one-hot so the check is deterministic."""
    V, G, B = 6, 4, 7
    logits = jax.random.normal(jax.random.PRNGKey(4), (B, G, V))
    q = jax.nn.log_softmax(logits)
    draft = jnp.argmax(logits, -1)
    bonus = jnp.full((B, V), -1e9).at[:, 5].set(0.0)
    out, n = verify_rejection(jax.random.PRNGKey(5), draft, q, logits, bonus)
    assert np.all(np.asarray(n) == G + 1)
    assert np.all(np.asarray(out)[:, :G] == np.asarray(draft))
    assert np.all(np.asarray(out)[:, G] == 5)


def test_rejection_conditional_next_token_matches_target():
    """Statistical losslessness beyond the first token: conditioned on the
    first draft token being accepted with value x, the second output token
    is distributed as the target's conditional p2(. | x) — i.e. repeated
    speculative sampling reproduces the target's ancestral process."""
    V, G, N = 3, 2, 6000
    key = jax.random.PRNGKey(6)
    k1, k2, k3, k4, kr = jax.random.split(key, 5)
    q1 = jax.random.normal(k1, (V,))
    p1 = q1 + 0.3 * jax.random.normal(k2, (V,))     # close -> high acceptance
    Q2 = jax.random.normal(k3, (V, V))
    P2 = Q2 + 0.3 * jax.random.normal(k4, (V, V))

    def one(k):
        ka, kb, kv = jax.random.split(k, 3)
        d0 = jax.random.categorical(ka, q1)
        d1 = jax.random.categorical(kb, Q2[d0])
        draft = jnp.stack([d0, d1])[None]
        draft_lp = jnp.stack([jax.nn.log_softmax(q1),
                              jax.nn.log_softmax(Q2[d0])])[None]
        tl = jnp.stack([p1, P2[d0]])[None]
        out, n = verify_rejection(kv, draft, draft_lp, tl, p1[None])
        return out[0], n[0]

    outs, ns = jax.vmap(one)(jax.random.split(kr, N))
    outs, ns = np.asarray(outs), np.asarray(ns)
    p2 = np.asarray(jax.nn.softmax(P2, -1))
    for x in range(V):
        sel = (ns >= 2) & (outs[:, 0] == x)     # draft token x accepted
        n_x = int(sel.sum())
        assert n_x > 100, "acceptance too low for a meaningful check"
        emp = np.bincount(outs[sel, 1], minlength=V) / n_x
        tol = 4 * np.sqrt(p2[x] * (1 - p2[x]) / n_x) + 0.01
        assert np.all(np.abs(emp - p2[x]) < tol), (x, emp, p2[x])


def test_rejection_identical_models_accept_everything():
    V, G, B = 7, 4, 8
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (B, G, V))
    q = jax.nn.log_softmax(logits)
    draft = jnp.argmax(logits, -1)
    # drafter proposes argmax, and q == p pointwise -> p/q = 1 -> all accepted
    out, n = verify_rejection(jax.random.PRNGKey(1), draft, q, logits,
                              logits[:, -1])
    assert np.all(np.asarray(n) == G + 1)
