"""MoE ragged path vs dense oracle; SSD chunked vs naive (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:     # declared dep; degrade so collection never hard-fails
    from _hypothesis_fallback import given, settings, st

from repro.config import ModelConfig, MoEConfig
from repro.models.moe import apply_moe, dense_moe_reference, moe_params
from repro.models.ssm import ssd_chunked, ssd_reference


def _cfg(d, E, k, f, shared):
    return ModelConfig(name="t", family="moe", n_layers=1, d_model=d,
                       n_heads=2, n_kv_heads=2, d_ff=f, vocab=16,
                       moe=MoEConfig(n_routed=E, top_k=k, d_ff=f,
                                     n_shared=shared)), \
        MoEConfig(n_routed=E, top_k=k, d_ff=f, n_shared=shared)


@given(st.integers(0, 10_000), st.integers(2, 8), st.integers(1, 3),
       st.integers(1, 24), st.booleans())
@settings(max_examples=25, deadline=None)
def test_moe_ragged_matches_dense(seed, E, k, n_tokens, shared):
    k = min(k, E)
    cfg, moe = _cfg(8, E, k, 16, 1 if shared else 0)
    p = moe_params(jax.random.PRNGKey(seed), cfg, moe)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (n_tokens, 8))
    out, aux = apply_moe(p, x, cfg, moe)
    ref = dense_moe_reference(p, x, cfg, moe)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    assert np.isfinite(float(aux)) and float(aux) >= 0


def test_moe_grads_flow():
    cfg, moe = _cfg(8, 4, 2, 16, 1)
    p = moe_params(jax.random.PRNGKey(0), cfg, moe)
    x = jax.random.normal(jax.random.PRNGKey(1), (6, 8))

    def loss(p):
        out, aux = apply_moe(p, x, cfg, moe)
        return jnp.sum(out ** 2) + aux

    g = jax.grad(loss)(p)
    norms = [float(jnp.sum(jnp.abs(v))) for v in jax.tree.leaves(g)]
    assert all(np.isfinite(n) for n in norms)
    assert sum(norms) > 0


@given(st.integers(0, 10_000), st.integers(1, 40), st.integers(1, 8))
@settings(max_examples=20, deadline=None)
def test_ssd_chunked_matches_reference(seed, L, chunk):
    b, H, P, G, N = 2, 4, 8, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (b, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    B = jax.random.normal(ks[3], (b, L, G, N))
    C = jax.random.normal(ks[4], (b, L, G, N))
    y1, s1 = ssd_chunked(x, dt, A, B, C, chunk=chunk)
    y2, s2 = ssd_reference(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=2e-4, atol=2e-4)


def test_ssd_state_carry_composes():
    """Running [0:a] then [a:L] with carried state == running [0:L]."""
    b, L, H, P, G, N, a = 1, 24, 2, 4, 1, 8, 10
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(ks[0], (b, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    B = jax.random.normal(ks[3], (b, L, G, N))
    C = jax.random.normal(ks[4], (b, L, G, N))
    y_full, s_full = ssd_chunked(x, dt, A, B, C, chunk=8)
    y1, s1 = ssd_chunked(x[:, :a], dt[:, :a], A, B[:, :a], C[:, :a], chunk=8)
    y2, s2 = ssd_chunked(x[:, a:], dt[:, a:], A, B[:, a:], C[:, a:], chunk=8,
                         initial_state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               rtol=1e-4, atol=1e-4)
