"""Paged KV/SSM pool (DESIGN.md §2.8): the page-pool cache engine must
be invisible — bitwise-identical logits and committed tokens vs the
reserved-capacity resident path — across attention / SSM / hybrid / MLA
/ sliding-window families, through eviction-and-reuse, speculative
snapshot rollback and long-context admission; plus allocator properties
(no leaks, no aliasing, deterministic block tables) and the paged Pallas
decode kernel against its oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:     # declared dep; degrade so collection never hard-fails
    from _hypothesis_fallback import given, settings, st

from conftest import TINY_MAX_LEN as MAX_LEN, tiny_model_cfg as _tiny
from repro.config import CoSineConfig, ModelConfig
from repro.models import model as M
from repro.serving.runner import ModelRunner, PagedSlotCacheManager
from test_runner_slots import _tiny_exotic


def _pair(cfg, n_slots=2, max_len=MAX_LEN, **paged_kw):
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    res = ModelRunner(cfg, params, max_len=max_len, n_slots=n_slots)
    pag = ModelRunner(cfg, params, max_len=max_len, n_slots=n_slots,
                      paged=True, **paged_kw)
    return res, pag, cfg


@pytest.fixture(params=["attn", "ssm", "hybrid"])
def runners(request):
    return _pair(_tiny(request.param), page_size=16)


def _eq(a, b):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------- bitwise equivalence
def test_paged_matches_resident_bitwise(runners):
    """Prefill, batched decode, chain verification and ragged commit all
    produce the exact same bits on the paged pool as on the resident
    slot cache — the paged read view is structurally the resident
    layout, and the write scatter lands on the same columns."""
    res, pag, cfg = runners
    rng = np.random.default_rng(0)
    rids = [0, 1, 2]                       # third admission grows the pool
    for rid in rids:
        toks = rng.integers(0, cfg.vocab, 7 + 3 * rid)
        la, _ = res.prefill_request(rid, toks)
        lb, _ = pag.prefill_request(rid, toks)
        _eq(la, lb)

    step = rng.integers(0, cfg.vocab, 3)
    la, _ = res.decode(rids, step)
    lb, _ = pag.decode(rids, step)
    _eq(la, lb)

    G = 4
    vt = rng.integers(0, cfg.vocab, (3, G))
    rel = np.broadcast_to(np.arange(G, dtype=np.int32), (3, G))
    mask = np.broadcast_to(np.tril(np.ones((G, G), bool)), (3, G, G))
    _eq(res.verify(rids, vt, rel, mask), pag.verify(rids, vt, rel, mask))

    commits = {0: [1, 2], 1: [3], 2: [4, 5, 6]}
    ta, tb = res.extend_committed(commits), pag.extend_committed(commits)
    for rid in commits:
        _eq(ta[rid], tb[rid])
        assert res.length(rid) == pag.length(rid)


@pytest.mark.parametrize("kind", ["mla", "swa"])
def test_paged_matches_resident_exotic(kind):
    """MLA latent caches and sliding-window ring caches page too: SWA
    maps a fixed ring of pages (write columns pos % C land on the same
    pages as the resident ring), MLA pages the joint latent rows."""
    res, pag, cfg = _pair(_tiny_exotic(kind), page_size=16)
    rng = np.random.default_rng(13)
    toks = rng.integers(0, cfg.vocab, 13)
    la, _ = res.prefill_request(0, toks)
    lb, _ = pag.prefill_request(0, toks)
    _eq(la, lb)
    for t in rng.integers(0, cfg.vocab, 4):
        la, _ = res.decode([0], np.asarray([t]))
        lb, _ = pag.decode([0], np.asarray([t]))
        _eq(la, lb)


def test_paged_int8_kv_matches_resident_int8():
    """kv_dtype='int8' on the paged pool: the page-pool stores int8 KV
    with per-(token, head) scales (k_scale/v_scale leaves page, gather
    and scatter exactly like k/v), so paged+int8 is bitwise-identical
    to resident+int8 across prefill, decode, verify and commit."""
    res, pag, cfg = _pair(_tiny("attn").with_overrides(kv_dtype="int8"),
                          page_size=16)
    pool = pag.slots.cache["stages"][0][0]["self"]
    assert pool["k"].dtype == jnp.int8 and "k_scale" in pool
    rng = np.random.default_rng(7)
    rids = [0, 1]
    for rid in rids:
        toks = rng.integers(0, cfg.vocab, 9 + 4 * rid)
        la, _ = res.prefill_request(rid, toks)
        lb, _ = pag.prefill_request(rid, toks)
        _eq(la, lb)
    for t in rng.integers(0, cfg.vocab, (3, 2)):
        la, _ = res.decode(rids, t)
        lb, _ = pag.decode(rids, t)
        _eq(la, lb)
    G = 4
    vt = rng.integers(0, cfg.vocab, (2, G))
    rel = np.broadcast_to(np.arange(G, dtype=np.int32), (2, G))
    mask = np.broadcast_to(np.tril(np.ones((G, G), bool)), (2, G, G))
    _eq(res.verify(rids, vt, rel, mask), pag.verify(rids, vt, rel, mask))
    commits = {0: [1, 2, 3], 1: [4]}
    ta, tb = res.extend_committed(commits), pag.extend_committed(commits)
    for rid in commits:
        _eq(ta[rid], tb[rid])


def test_mla_int8_kv_rejected_at_construction():
    """The MLA latent cache has no quantized layout: kv_dtype='int8'
    with attention='mla' must fail loudly at cache construction (both
    resident and paged), not silently keep a bf16 pool."""
    cfg = _tiny_exotic("mla").with_overrides(kv_dtype="int8")
    with pytest.raises(ValueError, match="mla"):
        M.init_cache(cfg, 1, MAX_LEN)
    with pytest.raises(ValueError, match="mla"):
        M.init_paged_cache(cfg, 1, page_size=16)


def test_paged_swa_prompt_past_ring_capacity():
    """A prompt longer than the ring (300 tokens, window 16) wraps the
    paged ring exactly like the resident one."""
    res, pag, cfg = _pair(_tiny_exotic("swa"), max_len=512, page_size=16)
    rng = np.random.default_rng(4)
    toks = rng.integers(0, cfg.vocab, 300)
    la, _ = res.prefill_request(0, toks)
    lb, _ = pag.prefill_request(0, toks)
    _eq(la, lb)
    for t in rng.integers(0, cfg.vocab, 3):
        da, _ = res.decode([0], np.asarray([int(t)]))
        db, _ = pag.decode([0], np.asarray([int(t)]))
        _eq(da, db)


def test_paged_eviction_reuses_pages_exactly(runners):
    """Dropping a request returns its pages to the free list; a new
    tenant reusing those physical pages sees no KV leakage — its logits
    stay bitwise equal to the resident path."""
    res, pag, cfg = runners
    rng = np.random.default_rng(1)
    for rid in (0, 1):
        toks = rng.integers(0, cfg.vocab, 12)
        res.prefill_request(rid, toks)
        pag.prefill_request(rid, toks)
    held_before = pag.slots.pages_held()
    res.drop(1)
    pag.drop(1)
    assert pag.slots.pages_held() < held_before

    toks = rng.integers(0, cfg.vocab, 17)
    la, _ = res.prefill_request(9, toks)
    lb, _ = pag.prefill_request(9, toks)
    _eq(la, lb)
    step = rng.integers(0, cfg.vocab, 2)
    la, _ = res.decode([0, 9], step)
    lb, _ = pag.decode([0, 9], step)
    _eq(la, lb)


def test_paged_snapshot_is_rollback(runners):
    """Speculative snapshots gather the mapped pages into a plain copy:
    drafting on the snapshot never touches the pool, and discarding it
    is a complete rollback — then committed decode still matches the
    resident path bit-for-bit."""
    res, pag, cfg = runners
    rng = np.random.default_rng(2)
    toks = rng.integers(0, cfg.vocab, 9)
    res.prefill_request(0, toks)
    pag.prefill_request(0, toks)

    held = pag.slots.pages_held()
    snap_a = res.speculative_caches([0])
    snap_b = pag.speculative_caches([0])
    for t in rng.integers(0, cfg.vocab, 3):
        la, snap_a = res.decode([0], np.asarray([t]), caches=snap_a)
        lb, snap_b = pag.decode([0], np.asarray([t]), caches=snap_b)
        _eq(la, lb)
    # drafting allocated nothing and advanced nothing in the pool
    assert pag.slots.pages_held() == held
    assert pag.length(0) == len(toks)

    step = int(rng.integers(0, cfg.vocab))
    la, _ = res.decode([0], np.asarray([step]))
    lb, _ = pag.decode([0], np.asarray([step]))
    _eq(la, lb)


def test_long_context_overflows_reserved_but_fits_paged():
    """The resident cache reserves max_len columns per slot; the paged
    pool holds whatever pages a request actually touches. A prompt far
    past max_len admits fine on the paged pool and matches a per-request
    reference cache sized to fit."""
    cfg = _tiny("attn")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    pag = ModelRunner(cfg, params, max_len=32, n_slots=2, paged=True,
                      page_size=16)
    rng = np.random.default_rng(6)
    toks = rng.integers(0, cfg.vocab, 100)
    lg, _ = pag.prefill_request(0, toks)

    cache = M.init_cache(cfg, 1, 256, dtype=jnp.float32)
    rlg, cache, _ = M.prefill(params, cfg, jnp.asarray(toks)[None], cache)
    np.testing.assert_allclose(lg, np.asarray(rlg[0, -1, :cfg.vocab]),
                               atol=1e-5)
    assert pag.length(0) == 100
    assert pag.slots.pages_held() >= 100 // 16
    for t in rng.integers(0, cfg.vocab, 3):
        dl, _ = pag.decode([0], np.asarray([int(t)]))
        rl, cache, _ = M.decode_step(params, cfg, jnp.asarray([[int(t)]]),
                                     cache)
        np.testing.assert_allclose(dl[0], np.asarray(rl[0, 0, :cfg.vocab]),
                                   atol=1e-5)


# --------------------------------------------------------- allocator physics
def _ops_stream(rng, n_ops, max_rids=6):
    """A random admit/write/release schedule over a few request ids."""
    ops, live = [], set()
    for _ in range(n_ops):
        r = int(rng.integers(0, max_rids))
        kind = rng.choice(["admit", "write", "release"])
        if kind == "admit" and r not in live:
            ops.append(("admit", r)); live.add(r)
        elif kind == "write" and r in live:
            ops.append(("write", r, int(rng.integers(1, 40))))
        elif kind == "release" and r in live:
            ops.append(("release", r)); live.discard(r)
    return ops


def _replay(mgr, ops):
    for op in ops:
        if op[0] == "admit":
            mgr.admit(op[1])
        elif op[0] == "write":
            mgr.prepare([op[1]], write=op[2])
            mgr.advance(op[1], op[2])
        else:
            mgr.release(op[1])


def _mgr(kind="attn", **kw):
    kw.setdefault("page_size", 16)
    return PagedSlotCacheManager(_tiny(kind), MAX_LEN, n_slots=2, **kw)


@given(st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_no_page_leaks(seed):
    """Conservation: mapped pages + free pages == pool size minus the
    two reserved pages, at every point of a random schedule; releasing
    everything returns the allocator to empty."""
    rng = np.random.default_rng(seed)
    mgr = _mgr()
    ops = _ops_stream(rng, 30)
    for op in ops:
        _replay(mgr, [op])
        assert (mgr.pages_held() + len(mgr._free_pages)
                == mgr.n_pages - mgr._RESERVED)
    for rid in list(mgr.tables):
        mgr.release(rid)
    assert mgr.pages_held() == 0
    assert len(mgr._free_pages) == mgr.n_pages - mgr._RESERVED


@given(st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_no_page_aliasing(seed):
    """No physical page is ever mapped by two requests (or present in a
    table and on the free list) — including across pool growth."""
    rng = np.random.default_rng(seed)
    mgr = _mgr()
    for op in _ops_stream(rng, 30):
        _replay(mgr, [op])
        mapped = [p for t in mgr.tables.values() for p in t if p >= 0]
        assert len(mapped) == len(set(mapped))
        assert not set(mapped) & set(mgr._free_pages)
        assert all(p >= mgr._RESERVED for p in mapped)


@given(st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_block_tables_deterministic(seed):
    """The allocator is a pure function of the op schedule: two managers
    replaying the same stream hold identical block tables and emit
    identical page views (batch composition independence)."""
    rng = np.random.default_rng(seed)
    ops = _ops_stream(rng, 25)
    a, b = _mgr(), _mgr()
    _replay(a, ops)
    _replay(b, ops)
    assert a.tables == b.tables
    live = sorted(a.tables)
    if live:
        _eq(a.view(live), b.view(live))


def test_windowed_tables_are_fixed_rings():
    """SWA block tables are rings of C/page_size entries, page_size
    fitted down until it divides the ring capacity."""
    mgr = PagedSlotCacheManager(_tiny_exotic("swa"), MAX_LEN, n_slots=2,
                                page_size=64)
    assert mgr.ring_pages > 0
    assert mgr.ring_pages * mgr.page_size % mgr.page_size == 0
    mgr.admit(0)
    assert len(mgr.tables[0]) == mgr.ring_pages
    mgr.prepare([0], write=mgr.page_size * mgr.ring_pages + 5)
    mgr.advance(0, mgr.page_size * mgr.ring_pages + 5)
    # wrapping never grows the ring
    assert len(mgr.tables[0]) == mgr.ring_pages
    assert mgr.pages_held() == mgr.ring_pages


def test_fragmentation_accounting():
    mgr = _mgr()
    assert mgr.fragmentation() == 0.0
    mgr.admit(0)
    mgr.prepare([0], write=mgr.page_size)       # exactly one full page
    mgr.advance(0, mgr.page_size)
    assert mgr.fragmentation() == 0.0
    mgr.prepare([0], write=1)                   # one token on a fresh page
    mgr.advance(0, 1)
    held = mgr.pages_held() * mgr.page_size
    assert abs(mgr.fragmentation()
               - (1.0 - (mgr.page_size + 1) / held)) < 1e-12


# ----------------------------------------------------------- paged kernel
def _paged_fixture(rng, B, H, G, Dk, Dv, ps, lengths):
    """Contiguous-prefix page layout: request b holds [0, L_b)."""
    n_pages = 2 + sum(-(-L // ps) for L in lengths)
    nv = max(-(-L // ps) for L in lengths)
    nv = 1 << (nv - 1).bit_length()
    q = jnp.asarray(rng.normal(size=(B, H, G, Dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(n_pages, H, ps, Dk)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(n_pages, H, ps, Dv)), jnp.float32)
    pos = np.full((n_pages, ps), -1, np.int32)
    tbl = np.ones((B, nv), np.int32)            # NULL page filler
    nxt = 2
    for b, L in enumerate(lengths):
        for j in range(-(-L // ps)):
            n = min(ps, L - j * ps)
            pos[nxt, :n] = j * ps + np.arange(n)
            tbl[b, j] = nxt
            nxt += 1
    qp = jnp.asarray([L - 1 for L in lengths], jnp.int32)
    return q, k, v, jnp.asarray(pos), qp, jnp.asarray(tbl)


@pytest.mark.parametrize("window", [0, 16])
@pytest.mark.parametrize("G", [1, 4])
def test_paged_kernel_matches_oracle(window, G):
    from repro.kernels.decode_attention.ops import decode_attention_paged
    from repro.kernels.decode_attention.ref import decode_attention_paged_ref
    rng = np.random.default_rng(0)
    args = _paged_fixture(rng, B=3, H=2, G=G, Dk=16, Dv=16, ps=8,
                          lengths=[25, 9, 31])
    out = decode_attention_paged(*args, scale=0.25, window=window)
    ref = decode_attention_paged_ref(*args, scale=0.25, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_paged_kernel_skips_unmapped_pages():
    """NULL-page entries (slot_pos all -1) are exact no-ops: shrinking
    the view to only the mapped pages changes nothing."""
    from repro.kernels.decode_attention.ops import decode_attention_paged
    rng = np.random.default_rng(1)
    q, k, v, pos, qp, tbl = _paged_fixture(rng, B=2, H=2, G=4, Dk=16,
                                           Dv=16, ps=8, lengths=[9, 17])
    wide = jnp.concatenate(
        [tbl, jnp.ones((2, 4), jnp.int32)], axis=1)     # extra NULL entries
    out = decode_attention_paged(q, k, v, pos, qp, tbl, scale=0.25)
    out_w = decode_attention_paged(q, k, v, pos, qp, wide, scale=0.25)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_w), atol=1e-6)


# ----------------------------------------------------------- engine lossless
@pytest.mark.parametrize("strategy", ["cosine", "specinfer"])
def test_engine_committed_tokens_identical_paged(strategy):
    """End to end: the engine with paged_pool=True commits exactly the
    same tokens as with the resident cache — same seed, same prompts,
    greedy speculative decoding (random-init models; losslessness does
    not require trained weights)."""
    from repro.serving.engine import SpeculativeEngine
    tcfg = _tiny("hybrid")
    tparams = M.init_params(jax.random.PRNGKey(0), tcfg)
    dcfg = ModelConfig(name="tiny-draft", family="dense", n_layers=1,
                       d_model=48, n_heads=2, n_kv_heads=2, head_dim=16,
                       d_ff=96, vocab=50, tie_embeddings=True,
                       dtype="float32")
    drafters = [(dcfg, M.init_params(jax.random.PRNGKey(i + 1), dcfg),
                 f"d{i}") for i in range(2)]
    rng = np.random.default_rng(3)
    prompts = [rng.integers(1, 50, 8).tolist() for _ in range(3)]

    outs = []
    for paged in (False, True):
        cos = CoSineConfig(n_drafters=2, draft_len=4, drafters_per_request=2,
                           tree_width=2, paged_pool=paged, page_size=16)
        eng = SpeculativeEngine((tcfg, tparams), drafters, cos,
                                strategy=strategy, max_len=MAX_LEN, seed=0)
        for p in prompts:
            eng.submit(p, max_new_tokens=10, domain="d0")
        eng.run()
        outs.append({r.rid: list(r.generated) for r in eng.pool.completed})
    assert outs[0] == outs[1]
