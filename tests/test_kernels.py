"""Per-kernel shape/dtype sweeps vs pure-jnp oracles (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import (decode_attention,
                                                decode_attention_slots)
from repro.kernels.decode_attention.ref import (decode_attention_ref,
                                                decode_attention_slots_ref)
from repro.kernels.ssd_scan.ops import ssd
from repro.kernels.ssd_scan.ref import ssd_reference
from repro.kernels.tree_attention.ops import tree_attention
from repro.kernels.tree_attention.ref import tree_attention_ref


def _r(k, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(k), shape)
    return x.astype(dtype)


TREE_CASES = [
    # (B, H, R, S, M, Dk, Dv, window, dtype)
    (1, 1, 4, 16, 4, 16, 16, 0, jnp.float32),
    (2, 2, 12, 40, 12, 32, 16, 0, jnp.float32),
    (2, 1, 16, 64, 8, 64, 64, 24, jnp.float32),
    (1, 4, 8, 100, 16, 128, 128, 0, jnp.bfloat16),
    (3, 2, 24, 33, 10, 48, 32, 10, jnp.float32),
]


@pytest.mark.parametrize("case", TREE_CASES)
def test_tree_attention_matches_ref(case):
    B, H, R, S, Msz, Dk, Dv, window, dtype = case
    q = _r(1, (B, H, R, Dk), dtype)
    kc, vc = _r(2, (B, H, S, Dk), dtype), _r(3, (B, H, S, Dv), dtype)
    ks, vs = _r(4, (B, H, Msz, Dk), dtype), _r(5, (B, H, Msz, Dv), dtype)
    n_valid = max(S - 7, 1)
    cp = jnp.broadcast_to(jnp.arange(S), (B, S)).astype(jnp.int32)
    cp = jnp.where(cp < n_valid, cp, -1)
    qp = n_valid + jnp.broadcast_to(jnp.arange(R) // 2, (B, R)).astype(jnp.int32)
    mask = jax.random.bernoulli(jax.random.PRNGKey(6), 0.5, (B, R, Msz))
    mask = mask | (jnp.arange(R)[:, None] == jnp.arange(Msz)[None, :])
    out = tree_attention(q, kc, vc, cp, ks, vs, qp, mask, scale=0.18,
                         window=window, interpret=True, block_q=8, block_k=16)
    ref = tree_attention_ref(q, kc, vc, cp, ks, vs, qp, mask, scale=0.18,
                             window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=tol, atol=tol)


DECODE_CASES = [
    (1, 1, 1, 16, 16, 0, jnp.float32),
    (2, 2, 8, 64, 32, 0, jnp.float32),
    (2, 4, 4, 100, 64, 24, jnp.float32),
    (4, 1, 14, 128, 128, 0, jnp.bfloat16),
]


@pytest.mark.parametrize("case", DECODE_CASES)
def test_decode_attention_matches_ref(case):
    B, H, G, S, D, window, dtype = case
    q = _r(1, (B, H, G, D), dtype)
    kc, vc = _r(2, (B, H, S, D), dtype), _r(3, (B, H, S, D), dtype)
    cp = jnp.broadcast_to(jnp.arange(S), (B, S)).astype(jnp.int32)
    cp = jnp.where(cp < S - 3, cp, -1)
    qp = jnp.full((B,), S - 3, jnp.int32)
    out = decode_attention(q, kc, vc, cp, qp, scale=0.2, window=window,
                           interpret=True, block_k=32)
    ref = decode_attention_ref(q, kc, vc, cp, qp, scale=0.2, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("case", DECODE_CASES[:3])
def test_decode_attention_slots_matches_ref(case):
    """Slot-indexed reads: a pool larger than the active batch, rows
    selected by slot_idx (incl. a repeated scratch row), must match both
    the slot-aware oracle and plain decode on pre-gathered rows."""
    B, H, G, S, D, window, dtype = case
    pool = B + 3
    q = _r(1, (B, H, G, D), dtype)
    kc, vc = _r(2, (pool, H, S, D), dtype), _r(3, (pool, H, S, D), dtype)
    cp = jnp.broadcast_to(jnp.arange(S), (pool, S)).astype(jnp.int32)
    cp = jnp.where(cp < S - 3, cp, -1)
    qp = jnp.full((B,), S - 3, jnp.int32)
    # active rows scattered through the pool; row 0 acts as scratch
    slot_idx = (jnp.arange(B, dtype=jnp.int32) * 2 + 1) % pool
    out = decode_attention_slots(q, kc, vc, cp, qp, slot_idx, scale=0.2,
                                 window=window, interpret=True, block_k=32)
    ref = decode_attention_slots_ref(q, kc, vc, cp, qp, slot_idx, scale=0.2,
                                     window=window)
    gathered = decode_attention(
        q, jnp.take(kc, slot_idx, axis=0), jnp.take(vc, slot_idx, axis=0),
        jnp.take(cp, slot_idx, axis=0), qp, scale=0.2, window=window,
        interpret=True, block_k=32)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=tol, atol=tol)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(gathered))


SSD_CASES = [
    # (b, L, H, P, G, N, chunk, dtype)
    (1, 16, 2, 8, 1, 8, 8, jnp.float32),
    (2, 50, 8, 16, 2, 8, 16, jnp.float32),
    (2, 33, 4, 32, 4, 16, 8, jnp.float32),
    (1, 64, 8, 64, 1, 32, 32, jnp.float32),
]


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_kernel_matches_recurrence(case):
    b, L, H, P, G, N, chunk, dtype = case
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    x = jax.random.normal(ks[0], (b, L, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    B = jax.random.normal(ks[3], (b, L, G, N))
    C = jax.random.normal(ks[4], (b, L, G, N))
    s0 = jax.random.normal(ks[5], (b, H, P, N)) * 0.1
    y1, f1 = ssd(x, dt, A, B, C, chunk=chunk, initial_state=s0, interpret=True)
    y2, f2 = ssd_reference(x, dt, A, B, C, initial_state=s0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2),
                               rtol=2e-4, atol=2e-4)
