"""Telemetry layer (repro/obs, DESIGN.md §2.6): metrics registry and
decision-log units, tracer/event-log ring bounding, trace integrity
against the engine's own accounting (spans tile, totals match
ServeStats, commit instants equal the iteration records), deterministic
byte-identical export, decision-log fidelity to what the controllers
actually applied, and the export/summarizer surface."""
import io
import json

import numpy as np
import pytest

from conftest import TINY_MAX_LEN as MAX_LEN, tiny_model_cfg as _tiny
from repro.config import CoSineConfig, ModelConfig
from repro.core.latency_model import LatencyModel
from repro.core.request_pool import RequestPool
from repro.core.scheduler import PipelineObservation, RequestScheduler
from repro.obs.export import (build_metrics, build_trace,
                              export_engine_trace)
from repro.obs.metrics import DecisionLog, MetricsRegistry
from repro.obs.summarize import stage_totals as sum_stage_totals, summarize
from repro.obs.trace import LIFECYCLE, STAGE, Tracer
from repro.serving.engine import SpeculativeEngine
from repro.serving.events import EventLog


# ----------------------------------------------------------- registry units
def test_metrics_registry_counters_gauges_histograms():
    m = MetricsRegistry()
    m.inc("serve.committed_tokens", 3)
    m.inc("serve.committed_tokens", 2)
    m.inc("draft.node_tokens", 8, node=0)
    m.inc("draft.node_tokens", 4, node=1)
    m.set_gauge("pipeline.queue_depth", 2)
    m.observe("serve.iter_ms", 0.5)
    m.observe("serve.iter_ms", 1e9)          # overflow bucket
    assert m.value("serve.committed_tokens") == 5
    assert m.value("draft.node_tokens", node=1) == 4
    assert m.value("missing", default=-1.0) == -1.0
    assert m.value("pipeline.queue_depth") == 2
    assert m.label_values("draft.node_tokens", "node") == ["0", "1"]
    h = m.histogram("serve.iter_ms")
    assert h.count == 2 and h.counts[0] == 1 and h.counts[-1] == 1
    d = m.to_dict()
    assert d["counters"]["draft.node_tokens{node=0}"] == 8
    assert d["gauges"]["pipeline.queue_depth"] == 2
    assert d["histograms"]["serve.iter_ms"]["count"] == 2
    # labeled names are sorted -> the flat dict has deterministic order
    assert list(d["counters"]) == sorted(d["counters"])


def test_decision_log_ring_bounded_and_ordered():
    log = DecisionLog(max_entries=4)
    for i in range(10):
        log.record(float(i), "lam" if i % 2 else "admission", mult=i)
    assert len(log) == 4 and log.n_dropped == 6
    seqs = [d.seq for d in log.entries]
    assert seqs == sorted(seqs) and seqs[-1] == 9
    assert all(d.kind == "lam" for d in log.by_kind("lam"))
    assert log.entries[-1].get("mult") == 9
    # the drop counter reaches the metrics export
    m = MetricsRegistry(max_decisions=2)
    for i in range(5):
        m.decisions.record(0.0, "lam", mult=i)
    assert m.to_dict()["decisions_dropped"] == 3
    assert len(m.to_dict()["decisions"]) == 2


def test_tracer_ring_bounded_and_stage_totals():
    tr = Tracer(max_spans=3)
    tr.span("verify", STAGE, "verify", 0.0, 10.0)
    tr.span("bubble", STAGE, "verify", 10.0, 14.0, cause="await_draft")
    tr.span("verify", STAGE, "verify", 14.0, 20.0)
    assert tr.stage_totals("verify") == (16.0, 4.0)
    tr.mark("commit", 7, 20.0, cohort=1, n_tokens=3)   # rolls the ring
    assert len(tr.spans) == 3 and tr.n_dropped == 1
    life = tr.by_track("req7")
    assert life and life[0].cat == LIFECYCLE and life[0].is_instant
    assert life[0].get("n_tokens") == 3
    assert "verify" in tr.stage_tracks()


def test_disabled_tracer_records_nothing():
    tr = Tracer(enabled=False)
    assert tr.span("verify", STAGE, "verify", 0.0, 1.0) is None
    assert tr.mark("commit", 0, 1.0) is None
    assert len(tr.spans) == 0 and tr.n_dropped == 0


def test_event_log_ring_bounded():
    log = EventLog(max_events=3)
    for i in range(5):
        log.emit(float(i), "verify", "verify_start")
    assert len(log.events) == 3 and log.n_dropped == 2
    # unbounded log never drops
    log2 = EventLog()
    for i in range(5):
        log2.emit(float(i), "verify", "verify_start")
    assert len(log2.events) == 5 and log2.n_dropped == 0


# ----------------------------------------- controller decision fidelity
def test_scheduler_decisions_record_applied_values():
    cfg = CoSineConfig(max_batch=4, lam=0.02)
    sched = RequestScheduler(cfg, LatencyModel(),
                             decisions=DecisionLog())
    obs = PipelineObservation(verify_busy_frac=0.3, queue_depth=1,
                              backlog=2)
    lam = sched.effective_lam(obs, now_ms=42.0)
    d = sched.decisions.by_kind("lam")[-1]
    assert d.t_ms == 42.0
    assert d.get("lam") == pytest.approx(lam)
    assert d.get("lam") == pytest.approx(cfg.lam * d.get("mult"))
    assert d.get("queue_depth") == 1 and d.get("backlog") == 2

    g = sched.balance_gamma(2, 64, n_drafters=1, now_ms=50.0)
    bd = sched.decisions.by_kind("balance_gamma")[-1]
    assert bd.get("gamma") == g
    assert bd.get("saturated") == sched.spec_saturated

    pool = RequestPool()
    r = pool.add(np.zeros(12, np.int32), 32)
    r.gamma = 4
    sched.update_gamma_feedback(r, n_committed=0,
                                verifier_busy_frac=1.5, now_ms=60.0)
    fd = sched.decisions.by_kind("gamma_feedback")[-1]
    assert fd.get("rid") == r.rid
    assert fd.get("gamma_from") == 4 and fd.get("gamma_to") == r.gamma
    assert r.gamma == 3
    # no-op feedback adds no entry (the log stays bounded by changes)
    n = len(sched.decisions)
    sched.update_gamma_feedback(r, n_committed=2,
                                verifier_busy_frac=1.0, now_ms=61.0)
    assert len(sched.decisions) == n


def test_slo_gamma_trim_is_logged_with_inputs():
    cfg = CoSineConfig(max_batch=4, slo_trim=True)
    sched = RequestScheduler(cfg, LatencyModel(),
                             decisions=DecisionLog())
    pool = RequestPool()
    # deadline nearly exhausted: the per-token budget forces a walk-down
    r = pool.add(np.zeros(64, np.int32), 32, arrival_ms=0.0,
                 deadline_ms=40.0)
    r.gamma = cfg.gamma_max
    g = sched.slo_gamma(r, now_ms=30.0)
    assert g < cfg.gamma_max
    d = sched.decisions.by_kind("slo_gamma")[-1]
    assert d.get("rid") == r.rid and d.get("gamma_to") == g
    assert d.get("headroom_ms") == pytest.approx(10.0)
    # overdue request: trimmed straight to the floor, also logged
    g2 = sched.slo_gamma(r, now_ms=100.0)
    d2 = sched.decisions.by_kind("slo_gamma")[-1]
    assert d2.get("gamma_to") == g2 == min(cfg.min_gamma, r.gamma)


# -------------------------------------------------------- engine-level
@pytest.fixture(scope="module")
def models():
    import jax
    from repro.models import model as M
    tcfg = _tiny("attn")
    tparams = M.init_params(jax.random.PRNGKey(0), tcfg)
    dcfg = ModelConfig(name="tiny-draft", family="dense", n_layers=1,
                       d_model=48, n_heads=2, n_kv_heads=2, head_dim=16,
                       d_ff=96, vocab=50, tie_embeddings=True,
                       dtype="float32")
    drafters = [(dcfg, M.init_params(jax.random.PRNGKey(i + 1), dcfg),
                 f"d{i}") for i in range(2)]
    return {"attn": (tcfg, tparams), "drafters": drafters}


def _engine(models, strategy, seed=0, **cos_kw):
    cos = CoSineConfig(n_drafters=2, draft_len=4, drafters_per_request=2,
                       tree_width=2, **cos_kw)
    return SpeculativeEngine(models["attn"], models["drafters"], cos,
                             strategy=strategy, max_len=MAX_LEN, seed=seed)


def _prompts(n, rng_seed=3, length=8):
    rng = np.random.default_rng(rng_seed)
    return [rng.integers(1, 50, length).tolist() for _ in range(n)]


def _run(models, strategy, seed=0, n=3, **cos_kw):
    eng = _engine(models, strategy, seed=seed, **cos_kw)
    for p, t in zip(_prompts(n), [0.0, 120.0, 700.0][:n]):
        eng.submit(p, max_new_tokens=8, arrival_ms=t)
    eng.run()
    return eng


def _assert_serial_tracks_tile(tracer):
    """Work/bubble spans on every serial stage track must not overlap
    (the cluster track legally overlaps node work and is excluded)."""
    for track in tracer.stage_tracks():
        spans = sorted((s for s in tracer.by_track(track)
                        if s.cat == STAGE and not s.is_instant),
                       key=lambda s: (s.t0_ms, s.seq))
        assert spans, track
        for a, b in zip(spans, spans[1:]):
            assert b.t0_ms >= a.t1_ms - 1e-9, \
                f"{track}: {a.name}@{a.t1_ms} overlaps {b.name}@{b.t0_ms}"


@pytest.mark.parametrize("strategy", ["cosine", "pipeinfer"])
def test_pipelined_trace_matches_stats_and_records(models, strategy):
    eng = _run(models, strategy)
    tr, stats = eng.tracer, eng.stats
    _assert_serial_tracks_tile(tr)
    # trace-accounted verify totals == ServeStats == the stage clock
    busy, idle = tr.stage_totals("verify")
    assert busy == pytest.approx(stats.verifier_busy_ms, abs=1e-6)
    assert idle == pytest.approx(stats.verifier_idle_ms, abs=1e-6)
    assert busy == pytest.approx(eng.executor.verify.busy_ms, abs=1e-6)
    # per-node draft tracks exist and match the node clocks
    for i, clk in enumerate(eng.executor.cluster.nodes):
        nbusy, _ = tr.stage_totals(f"draft{i}")
        assert nbusy == pytest.approx(clk.busy_ms, abs=1e-6)
    # commit instants land exactly at their record's iteration end
    end_of = {r.cohort: r.t_start_ms + r.t_iter_ms for r in stats.records}
    commits = [s for s in tr.spans
               if s.cat == LIFECYCLE and s.name == "commit"]
    assert commits
    for s in commits:
        assert s.cohort in end_of
        assert s.t0_ms == pytest.approx(end_of[s.cohort], abs=1e-9)
    # committed token counts round-trip through the lifecycle track
    assert sum(s.get("n_tokens") for s in commits) == stats.total_committed
    # every request's lifecycle is complete
    for r in eng.pool.completed:
        names = [s.name for s in tr.by_track(f"req{r.rid}")]
        for ev in ("arrival", "first_token", "complete"):
            assert ev in names, (r.rid, names)
    # random-init drafters reject constantly: invalidations are marked
    n_inv_marks = sum(1 for s in tr.spans if s.name == "invalidate")
    assert n_inv_marks == stats.n_invalidated > 0
    assert eng.metrics.value("pipeline.invalidated") == stats.n_invalidated


@pytest.mark.parametrize("strategy", ["ar", "specinfer"])
def test_coupled_trace_tiles_and_matches_stats(models, strategy):
    """The analytic-decomposition spans (prefill -> bubble(draft) ->
    verify) reproduce the coupled baselines' accounting too."""
    eng = _run(models, strategy)
    tr, stats = eng.tracer, eng.stats
    _assert_serial_tracks_tile(tr)
    busy, idle = tr.stage_totals("verify")
    assert busy == pytest.approx(stats.verifier_busy_ms, abs=1e-6)
    assert idle == pytest.approx(stats.verifier_idle_ms, abs=1e-6)
    if strategy == "specinfer":
        dbusy, _ = tr.stage_totals("draft")
        assert dbusy == pytest.approx(
            sum(r.draft_ms for r in stats.records), abs=1e-6)
        bubbles = [s for s in tr.by_track("verify") if s.name == "bubble"]
        assert bubbles and all(s.get("cause") == "draft" for s in bubbles)


def test_same_seed_export_is_byte_identical(models, tmp_path):
    """The determinism contract: two same-seed runs export byte-identical
    trace AND metrics JSON (the async-loop validation baseline)."""
    def export(tag):
        eng = _run(models, "cosine", seed=5)
        path = str(tmp_path / f"{tag}.json")
        export_engine_trace(eng, path)
        return (open(path, "rb").read(),
                open(str(tmp_path / f"{tag}.metrics.json"), "rb").read())

    t1, m1 = export("a")
    t2, m2 = export("b")
    assert t1 == t2
    assert m1 == m2
    # and a different workload genuinely changes the export (the
    # equality above is not vacuous)
    eng3 = _engine(models, "cosine", seed=5)
    for p, t in zip(_prompts(3), [0.0, 60.0, 900.0]):
        eng3.submit(p, max_new_tokens=8, arrival_ms=t)
    eng3.run()
    p3 = str(tmp_path / "c.json")
    export_engine_trace(eng3, p3)
    assert open(p3, "rb").read() != t1


def test_decision_log_explains_applied_lambda_and_gamma(models):
    eng = _run(models, "cosine")
    cfg, log = eng.cfg, eng.metrics.decisions
    lams = log.by_kind("lam")
    assert lams        # every plan() recorded its lambda with inputs
    for d in lams:
        assert d.get("lam") == pytest.approx(cfg.lam * d.get("mult"))
        assert cfg.lam_mult_min - 1e-9 <= d.get("mult") \
            <= cfg.lam_mult_max + 1e-9
    # random-init drafters commit ~1 token/iter: feedback shrinks gamma,
    # and each logged transition is a real, in-bounds single step
    fbs = log.by_kind("gamma_feedback")
    assert fbs
    for d in fbs:
        assert d.get("gamma_to") != d.get("gamma_from")
        assert cfg.min_gamma <= d.get("gamma_to") <= cfg.gamma_max
    # the decision stream lands in the metrics export, in seq order
    md = build_metrics(eng)
    assert len(md["decisions"]) == len(log)
    seqs = [d["seq"] for d in md["decisions"]]
    assert seqs == sorted(seqs)


def test_trace_export_shape_and_summarizer(models, tmp_path):
    eng = _run(models, "cosine")
    trace = build_trace(eng.tracer)
    evs = trace["traceEvents"]
    names = {e["name"] for e in evs if e["ph"] == "M"}
    assert {"process_name", "thread_name", "thread_sort_index"} <= names
    thread_names = {e["args"]["name"] for e in evs
                    if e["name"] == "thread_name"}
    assert "verify" in thread_names and "draft0" in thread_names
    for e in evs:
        assert e["pid"] == 1
        if e["ph"] == "X":
            assert e["dur"] >= 0.0 and "track" in e["args"]
        if e["ph"] == "i":
            assert e["s"] == "t"
    # projected request-track copies exist and are marked with the
    # source stage, so accounting consumers can exclude them
    proj = [e for e in evs if "stage" in e.get("args", {})]
    assert proj and all(e["args"]["track"].startswith("req")
                        for e in proj)
    # summarizer stage totals (µs) agree with the tracer's (ms)
    st = sum_stage_totals(evs)
    busy, idle = eng.tracer.stage_totals("verify")
    assert st["verify"][0] / 1000.0 == pytest.approx(busy, abs=1e-3)
    assert st["verify"][1] / 1000.0 == pytest.approx(idle, abs=1e-3)
    out = io.StringIO()
    summarize(trace, n_requests=2, out=out)
    text = out.getvalue()
    assert "stage occupancy" in text and "verify" in text
    assert "req 0" in text and "commit" in text
    # the check_regression gate recomputes the same vutil from the file
    from benchmarks.check_regression import trace_vutil
    path = str(tmp_path / "t.json")
    export_engine_trace(eng, path)
    tv, _, _ = trace_vutil(path)
    assert tv == pytest.approx(eng.stats.verifier_utilization, rel=1e-6)
    md = json.load(open(str(tmp_path / "t.metrics.json")))
    assert md["gauges"]["obs.spans_dropped"] == 0.0


def test_tracing_disabled_engine_still_serves(models):
    eng = _run(models, "cosine", enable_tracing=False)
    assert len(eng.tracer.spans) == 0
    assert len(eng.pool.completed) == 3
    assert eng.stats.total_committed == 24
    # decisions/metrics still flow (only span capture is off)
    assert eng.metrics.decisions.by_kind("lam")


def test_obs_max_events_bounds_engine_telemetry(models):
    eng = _run(models, "cosine", obs_max_events=32)
    assert len(eng.tracer.spans) <= 32
    assert len(eng.executor.log.events) <= 32
    assert eng.tracer.n_dropped > 0
    assert eng.executor.log.n_dropped > 0
    # the drop counters surface in the metrics export (satellite)
    md = build_metrics(eng)
    assert md["gauges"]["obs.spans_dropped"] == eng.tracer.n_dropped
    assert md["gauges"]["obs.events_dropped"] == eng.executor.log.n_dropped
    # serving itself is unaffected by the ring
    assert len(eng.pool.completed) == 3
