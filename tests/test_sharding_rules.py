"""Sharding-rule validity: every PartitionSpec divides its dim for all 10
archs (the dry-run compiles these for real; this is the fast structural
check that runs in the normal single-device test suite)."""
import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.distributed import sharding as sh
from repro.models import model as M

ARCH_IDS = sorted(ARCHS)


class FakeMesh:
    """Structural stand-in so spec rules can be checked on 1 CPU device."""
    def __init__(self, shape_map):
        self.shape = dict(shape_map)
        self.axis_names = tuple(shape_map)


SINGLE = FakeMesh({"data": 16, "model": 16})
MULTI = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _check_specs(shapes, specs, mesh):
    flat_s = jax.tree.leaves(shapes)
    flat_p = jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    assert len(flat_s) == len(flat_p)
    for arr, spec in zip(flat_s, flat_p):
        assert len(spec) <= len(arr.shape), (arr.shape, spec)
        for dim, axes in zip(arr.shape, spec):
            if axes is None:
                continue
            axes = (axes,) if isinstance(axes, str) else axes
            n = int(np.prod([mesh.shape[a] for a in axes]))
            assert dim % n == 0, (arr.shape, spec, dim, axes)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mode", ["train", "serve"])
def test_param_specs_divisible(arch, mode):
    cfg = ARCHS[arch]
    shapes = jax.eval_shape(lambda k: M.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    for mesh in (SINGLE, MULTI):
        specs = sh.param_specs(cfg, mesh, mode=mode)
        _check_specs(shapes, specs, mesh)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("batch", [32, 128])
def test_cache_specs_divisible(arch, batch):
    cfg = ARCHS[arch]
    for mesh in (SINGLE, MULTI):
        shapes, specs = sh.cache_specs(cfg, mesh, batch, 256)
        _check_specs(shapes, specs, mesh)


def test_batch_spec_fallbacks():
    assert sh.batch_spec(SINGLE, 256) == ("data",)
    assert sh.batch_spec(MULTI, 256) == ("pod", "data")
    assert sh.batch_spec(MULTI, 16) == ("data",)
    assert sh.batch_spec(MULTI, 1) is None
