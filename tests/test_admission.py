"""SLO-aware admission control (core/admission.py, DESIGN.md §2.5):
decide() policy units, zero-token latency-stat hardening, and the
engine-level chaos paths — overload shedding with exact accounting, and
priority preemption with a lossless re-admit. Engine tests use
random-init tiny models (losslessness does not need trained weights)."""
import jax
import numpy as np
import pytest

from benchmarks.common import completion_stats
from conftest import TINY_MAX_LEN as MAX_LEN, tiny_model_cfg as _tiny
from repro.config import CoSineConfig, ModelConfig
from repro.core.admission import AdmissionController
from repro.core.latency_model import LatencyModel
from repro.core.request_pool import Request, RequestPool
from repro.core.scheduler import PipelineObservation
from repro.models import model as M
from repro.serving.engine import SpeculativeEngine

SAT = PipelineObservation(verify_busy_frac=1.0, queue_depth=2)
IDLE = PipelineObservation(verify_busy_frac=0.3, queue_depth=0)


def _reqs(pool, specs):
    out = []
    for sp in specs:
        r = pool.add(np.zeros(sp.get("plen", 8), np.int32), 16,
                     arrival_ms=sp.get("arrival", 0.0),
                     deadline_ms=sp.get("deadline", float("inf")),
                     priority=sp.get("priority", 1))
        if sp.get("started"):
            r.generated = [1]
        out.append(r)
    return out


def _ctl(**kw):
    cfg = CoSineConfig(enable_admission=True, **kw)
    return AdmissionController(cfg, LatencyModel()), cfg


# ------------------------------------------------------------- decide()
def test_hopeless_shed_only_under_saturation():
    ctl, _ = _ctl()
    pool = RequestPool()
    hopeless, ok = _reqs(pool, [{"deadline": 1.0}, {"deadline": 1e9}])
    # idle verifier: a late request is still served best-effort
    dec = ctl.decide([hopeless, ok], now_ms=100.0, observation=IDLE)
    assert hopeless in dec.admit and not dec.shed
    # saturated: serving it is pure goodput loss -> shed
    dec = ctl.decide([hopeless, ok], now_ms=100.0, observation=SAT)
    assert dec.shed == [hopeless] and dec.admit == [ok]
    # ... but an empty pipe overrides saturation (liveness)
    dec = ctl.decide([hopeless, ok], now_ms=100.0, observation=SAT,
                     pipe_empty=True)
    assert not dec.shed


def test_started_requests_never_shed():
    ctl, _ = _ctl()
    pool = RequestPool()
    (started,) = _reqs(pool, [{"deadline": 1.0, "started": True}])
    dec = ctl.decide([started], now_ms=100.0, observation=SAT)
    assert dec.admit == [started] and not dec.shed


def test_queue_cap_bounds_cold_backlog():
    ctl, _ = _ctl(admit_queue_cap=2)
    pool = RequestPool()
    rs = _reqs(pool, [{"arrival": float(i)} for i in range(7)])
    dec = ctl.decide(rs, now_ms=10.0, observation=SAT)
    # worst-first: 2 admitted, 2 queued, overflow past 2x the cap shed
    assert len(dec.admit) == 2 and len(dec.queued) == 2
    assert len(dec.shed) == 3
    assert dec.admit == rs[:2]         # urgency order = arrival here
    # unsaturated: the cap does not apply
    dec = ctl.decide(rs, now_ms=10.0, observation=IDLE)
    assert len(dec.admit) == 7 and not dec.queued and not dec.shed


def test_preemption_picks_lowest_priority_victim():
    ctl, _ = _ctl(max_batch=2)
    pool = RequestPool()
    lo, mid, hi = _reqs(pool, [
        {"priority": 2, "started": True},
        {"priority": 1, "started": True},
        {"priority": 0}])
    # batch full: one protected in-flight slot + two active victims
    dec = ctl.decide([hi], now_ms=0.0, observation=SAT,
                     active=[lo, mid], n_protected=0)
    assert dec.preempt == [lo]          # lowest class evicted first
    # no inversion: an equal-priority arrival preempts nobody
    (peer,) = _reqs(pool, [{"priority": 2}])
    dec = ctl.decide([peer], now_ms=0.0, observation=SAT,
                     active=[lo, mid], n_protected=0)
    assert not dec.preempt


def test_preemption_respects_free_slots():
    ctl, _ = _ctl(max_batch=4)
    pool = RequestPool()
    lo, hi = _reqs(pool, [{"priority": 2, "started": True},
                          {"priority": 0}])
    # 4 slots, 1 protected, 1 victim -> 2 free: no need to preempt
    dec = ctl.decide([hi], now_ms=0.0, observation=SAT,
                     active=[lo], n_protected=1)
    assert not dec.preempt


# ---------------------------------------------- zero-token stat hardening
def test_completion_stats_ignores_zero_token_completions():
    ok = Request(rid=0, prompt=np.zeros(4, np.int32), max_new_tokens=4,
                 arrival_ms=0.0, generated=[1, 2], done=True,
                 finish_ms=100.0, first_token_ms=40.0)
    shed = Request(rid=1, prompt=np.zeros(4, np.int32), max_new_tokens=4,
                   arrival_ms=10.0, done=True, finish_ms=50.0,
                   shed_ms=50.0)
    s = completion_stats([ok, shed])
    assert s["ms_per_tok"] == pytest.approx(50.0)   # not skewed by shed
    assert s["p99"] == pytest.approx(50.0)
    assert s["ttft"] == pytest.approx(40.0)         # -1 sentinel excluded
    assert s["n_zero_tok"] == 1
    empty = completion_stats([shed])                # no samples at all
    assert empty["ms_per_tok"] == 0.0 and empty["p99"] == 0.0
    assert empty["ttft"] == 0.0


# ------------------------------------------------------ engine-level chaos
@pytest.fixture(scope="module")
def models():
    tcfg = _tiny("attn")
    tparams = M.init_params(jax.random.PRNGKey(0), tcfg)
    dcfg = ModelConfig(name="tiny-draft", family="dense", n_layers=1,
                       d_model=48, n_heads=2, n_kv_heads=2, head_dim=16,
                       d_ff=96, vocab=50, tie_embeddings=True,
                       dtype="float32")
    drafters = [(dcfg, M.init_params(jax.random.PRNGKey(i + 1), dcfg),
                 f"d{i}") for i in range(2)]
    return {"target": (tcfg, tparams), "drafters": drafters}


def _greedy_reference(cfg, params, prompt, n):
    import jax.numpy as jnp
    cache = M.init_cache(cfg, 1, MAX_LEN, dtype=jnp.float32)
    lg, cache, _ = M.prefill(params, cfg, jnp.asarray(prompt)[None, :],
                             cache)
    last = np.asarray(lg[0, -1, :cfg.vocab])
    out = []
    for _ in range(n):
        t = int(np.argmax(last))
        out.append(t)
        lg, cache, _ = M.decode_step(params, cfg, jnp.asarray([[t]]), cache)
        last = np.asarray(lg[0, 0, :cfg.vocab])
    return out


def _engine(models, strategy, **cos_kw):
    cos = CoSineConfig(n_drafters=2, draft_len=4, drafters_per_request=2,
                       tree_width=2, enable_admission=True, **cos_kw)
    return SpeculativeEngine(models["target"], models["drafters"], cos,
                             strategy=strategy, max_len=MAX_LEN, seed=0)


def _drain(eng, max_iters=3000):
    for _ in range(max_iters):
        if eng.step() is None:
            return
    raise AssertionError("engine did not drain")


def test_overload_burst_shed_accounting(models):
    """Burst 10 requests at ~4x past what max_batch=2 can serve inside
    the SLO: admission sheds the hopeless tail, never a started stream,
    and every submitted request is accounted completed-or-shed."""
    rng = np.random.default_rng(3)
    eng = _engine(models, "cosine", max_batch=2, default_slo_ms=400.0,
                  admit_queue_cap=4)
    for i in range(10):
        eng.submit(rng.integers(0, 50, 8), max_new_tokens=6,
                   arrival_ms=float(i * 5), priority=int(i % 3))
    _drain(eng)
    pool = eng.pool
    comp, shed = pool.completed, pool.shed
    # exact accounting: nothing stranded, nothing half-committed
    assert pool.n_submitted == len(comp) + len(shed) == 10
    assert pool.empty
    assert len(shed) >= 1 and len(comp) >= 1
    assert all(not r.generated and r.was_shed for r in shed)
    assert eng.stats.n_shed == len(shed)
    # losslessness survives the chaos: every surviving stream is exactly
    # the target's greedy continuation
    tcfg, tparams = models["target"]
    for r in comp:
        assert r.generated == _greedy_reference(tcfg, tparams, r.prompt,
                                                len(r.generated)), r.rid
    # stats pipeline is robust to the zero-token shed completions
    s = completion_stats(comp + shed)
    assert s["n_zero_tok"] == len(shed)
    assert np.isfinite(s["p99"]) and np.isfinite(s["ttft"])


def test_priority_preemption_and_lossless_readmit(models):
    """A high-priority arrival evicts the low-priority slot-holder
    (max_batch=1); the victim re-admits via re-prefill and still decodes
    the exact greedy continuation."""
    rng = np.random.default_rng(4)
    eng = _engine(models, "specinfer", max_batch=1)
    lo = eng.submit(rng.integers(0, 50, 24), max_new_tokens=8,
                    arrival_ms=0.0, priority=2)
    hi = eng.submit(rng.integers(0, 50, 4), max_new_tokens=4,
                    arrival_ms=400.0, priority=0)
    _drain(eng)
    assert lo.n_preemptions >= 1
    assert eng.stats.n_preempted >= 1
    assert not eng.stats.n_shed          # no deadlines -> nothing shed
    assert {r.rid for r in eng.pool.completed} == {lo.rid, hi.rid}
    # the preempted stream lost its caches, not its tokens: the re-admit
    # re-prefilled prompt+generated and the result is still greedy-exact
    tcfg, tparams = models["target"]
    assert lo.generated == _greedy_reference(tcfg, tparams, lo.prompt, 8)
    assert hi.generated == _greedy_reference(tcfg, tparams, hi.prompt, 4)
    # preemption is what bought the TTFT: the high-priority request got
    # its first token while the evicted stream was still unfinished,
    # instead of waiting out the victim's whole 8-token run
    assert hi.first_token_ms < lo.finish_ms
    assert hi.first_token_ms - hi.arrival_ms < 1000.0
