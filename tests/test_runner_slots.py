"""Slot-based runner equivalence: the slot-resident continuous-batching
cache engine (gather -> step -> scatter inside one jitted program) must
produce logits identical to the seed's per-request flow (independent
batch-1 caches) for prefill, decode, verify and extend — across
attention, SSM and hybrid families — including slot reuse after eviction
and slot-pool growth."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import TINY_MAX_LEN as MAX_LEN, tiny_model_cfg as _tiny
from repro.config import ModelConfig
from repro.models import model as M
from repro.serving.runner import ModelRunner, SlotCacheManager, slot_bucket

ATOL = 1e-5


class PerRequestReference:
    """The seed cache-ownership model: one batch-1 cache pytree per
    request, stepped independently (what stack_caches/split_cache
    round-trips computed)."""

    def __init__(self, cfg, params):
        self.cfg, self.params = cfg, params
        self.caches = {}

    def prefill(self, rid, toks):
        cache = M.init_cache(self.cfg, 1, MAX_LEN, dtype=jnp.float32)
        lg, cache, _ = M.prefill(self.params, self.cfg,
                                 jnp.asarray(toks, jnp.int32)[None], cache)
        self.caches[rid] = cache
        return np.asarray(lg[0, -1, : self.cfg.vocab])

    def decode(self, rid, tok):
        lg, self.caches[rid], _ = M.decode_step(
            self.params, self.cfg, jnp.asarray([[tok]], jnp.int32),
            self.caches[rid])
        return np.asarray(lg[0, 0, : self.cfg.vocab])

    def verify(self, rid, toks, rel_pos, seg_mask):
        cache = self.caches[rid]
        positions = cache["lengths"][:, None] + jnp.asarray(rel_pos,
                                                            jnp.int32)[None]
        lg, _, _ = M.verify_chunk(
            self.params, self.cfg, jnp.asarray(toks, jnp.int32)[None], cache,
            positions=positions,
            seg_mask=jnp.asarray(seg_mask, bool)[None], write=False)
        return np.asarray(lg[0, :, : self.cfg.vocab])

    def extend(self, rid, toks):
        lg, self.caches[rid], _ = M.extend(
            self.params, self.cfg, jnp.asarray(toks, jnp.int32)[None],
            self.caches[rid])
        return np.asarray(lg[0, -1, : self.cfg.vocab])


@pytest.fixture(params=["attn", "ssm", "hybrid"])
def pair(request):
    cfg = _tiny(request.param)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    # n_slots=2 so the third admission exercises slot-pool growth
    return (ModelRunner(cfg, params, max_len=MAX_LEN, n_slots=2),
            PerRequestReference(cfg, params), cfg)


def test_prefill_decode_verify_extend_match(pair):
    runner, ref, cfg = pair
    rng = np.random.default_rng(0)
    rids = [0, 1, 2]
    for rid in rids:
        toks = rng.integers(0, cfg.vocab, 7 + 3 * rid)
        lg_s, _ = runner.prefill_request(rid, toks)
        np.testing.assert_allclose(lg_s, ref.prefill(rid, toks), atol=ATOL)

    # batched decode (bucket pads 3 -> 4 with scratch rows)
    step = rng.integers(0, cfg.vocab, 3)
    lg_s, _ = runner.decode(rids, step)
    for i, rid in enumerate(rids):
        np.testing.assert_allclose(lg_s[i], ref.decode(rid, step[i]),
                                   atol=ATOL)

    # chain verification (no commit): logits match, caches untouched
    G = 4
    vt = rng.integers(0, cfg.vocab, (3, G))
    rel = np.broadcast_to(np.arange(G, dtype=np.int32), (3, G))
    mask = np.broadcast_to(np.tril(np.ones((G, G), bool)), (3, G, G))
    lg_s = runner.verify(rids, vt, rel, mask)
    for i, rid in enumerate(rids):
        np.testing.assert_allclose(lg_s[i], ref.verify(rid, vt[i], rel[i],
                                                       mask[i]), atol=ATOL)

    # ragged commit: per-request token counts differ (grouped by length)
    commits = {0: [1, 2], 1: [3], 2: [4, 5, 6]}
    tails = runner.extend_committed(commits)
    for rid, toks in commits.items():
        np.testing.assert_allclose(tails[rid], ref.extend(rid, toks),
                                   atol=ATOL)
        assert runner.length(rid) == int(ref.caches[rid]["lengths"][0])


def test_slot_reuse_after_eviction(pair):
    runner, ref, cfg = pair
    rng = np.random.default_rng(1)
    for rid in (0, 1):
        toks = rng.integers(0, cfg.vocab, 8)
        runner.prefill_request(rid, toks)
        ref.prefill(rid, toks)
    evicted_slot = runner.slots.slot_of[1]
    runner.drop(1)

    # the freed slot must be reused and fully reset (no KV/state leakage
    # from the previous tenant)
    toks = rng.integers(0, cfg.vocab, 11)
    lg_s, _ = runner.prefill_request(9, toks)
    assert runner.slots.slot_of[9] == evicted_slot
    np.testing.assert_allclose(lg_s, ref.prefill(9, toks), atol=ATOL)

    # survivors and the new tenant still decode identically
    step = rng.integers(0, cfg.vocab, 2)
    lg_s, _ = runner.decode([0, 9], step)
    np.testing.assert_allclose(lg_s[0], ref.decode(0, step[0]), atol=ATOL)
    np.testing.assert_allclose(lg_s[1], ref.decode(9, step[1]), atol=ATOL)


def test_speculative_snapshot_is_rollback(pair):
    runner, ref, cfg = pair
    rng = np.random.default_rng(2)
    toks = rng.integers(0, cfg.vocab, 9)
    runner.prefill_request(0, toks)
    ref.prefill(0, toks)

    # draft on a snapshot: advances the snapshot, not the slot cache
    snap = runner.speculative_caches([0])
    for t in rng.integers(0, cfg.vocab, 3):
        _, snap = runner.decode([0], np.asarray([t]), caches=snap)
    assert runner.length(0) == len(toks)

    # the slot cache then commits from its pre-draft state
    step = int(rng.integers(0, cfg.vocab))
    lg_s, _ = runner.decode([0], np.asarray([step]))
    np.testing.assert_allclose(lg_s[0], ref.decode(0, step), atol=ATOL)


def test_inplace_write_path_matches_gather_scatter(pair):
    """The resident write path (apply(..., slot_idx=...)) must be
    bit-identical to the legacy gather -> step -> scatter composition:
    same logits, same active-slot cache contents — across attention, SSM
    and hybrid families, including bucket padding to the scratch slot."""
    runner, _, cfg = pair
    params = runner.params
    rng = np.random.default_rng(7)
    rids = [0, 1, 2]
    for rid in rids:                       # third admission grows the pool
        runner.prefill_request(rid, rng.integers(0, cfg.vocab, 6 + rid))
    idx = runner.slots.padded_idx(rids)    # pads 3 -> 4 with scratch
    rows = int(idx.shape[0])
    cache = runner.slots.cache

    def active(c):
        """Cache contents of the active slots only (scratch excluded)."""
        act = jnp.asarray(sorted({int(s) for s in np.asarray(idx)
                                  if s != SlotCacheManager.SCRATCH}))
        stages = jax.tree.map(lambda x: jnp.take(x, act, axis=1),
                              c["stages"])
        return stages, jnp.take(c["lengths"], act)

    def assert_same(ca, cb):
        for a, b in zip(jax.tree.leaves(active(ca)),
                        jax.tree.leaves(active(cb))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # --- decode ---
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (rows, 1)), jnp.int32)
    lg_a, cache_a, _ = M.slot_decode_step(params, cfg, toks, cache, idx)
    sub = M.gather_slots(cache, idx)
    lg_b, sub, _ = M.decode_step(params, cfg, toks, sub)
    cache_b = M.scatter_slots(cache, sub, idx)
    np.testing.assert_array_equal(np.asarray(lg_a), np.asarray(lg_b))
    assert_same(cache_a, cache_b)

    # --- verify (no commit): logits match, caches untouched ---
    G = 3
    vt = jnp.asarray(rng.integers(0, cfg.vocab, (rows, G)), jnp.int32)
    rel = jnp.broadcast_to(jnp.arange(G, dtype=jnp.int32), (rows, G))
    mask = jnp.broadcast_to(jnp.tril(jnp.ones((G, G), bool)), (rows, G, G))
    lg_a = M.slot_verify_chunk(params, cfg, vt, cache_a, idx, rel, mask)
    sub = M.gather_slots(cache_b, idx)
    lg_b, _, _ = M.verify_chunk(params, cfg, vt, sub,
                                positions=sub["lengths"][:, None] + rel,
                                seg_mask=mask, write=False)
    np.testing.assert_array_equal(np.asarray(lg_a), np.asarray(lg_b))
    assert_same(cache_a, cache_b)

    # --- extend (speculative commit) ---
    et = jnp.asarray(rng.integers(0, cfg.vocab, (rows, 2)), jnp.int32)
    lg_a, cache_a, _ = M.slot_extend(params, cfg, et, cache_a, idx)
    sub = M.gather_slots(cache_b, idx)
    lg_b, sub, _ = M.extend(params, cfg, et, sub)
    cache_b = M.scatter_slots(cache_b, sub, idx)
    np.testing.assert_array_equal(np.asarray(lg_a), np.asarray(lg_b))
    assert_same(cache_a, cache_b)

    # --- eviction and slot reuse keep the paths aligned ---
    runner.slots.cache = cache_a
    runner.drop(1)
    runner.prefill_request(9, rng.integers(0, cfg.vocab, 5))
    idx2 = runner.slots.padded_idx([0, 9])
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 1)), jnp.int32)
    lg_a, cache_a2, _ = M.slot_decode_step(params, cfg, toks,
                                           runner.slots.cache, idx2)
    sub = M.gather_slots(runner.slots.cache, idx2)
    lg_b, _, _ = M.decode_step(params, cfg, toks, sub)
    np.testing.assert_array_equal(np.asarray(lg_a), np.asarray(lg_b))


def test_inplace_cross_attention_matches_gather_scatter():
    """Cross-attention layers (VLM-style frontend) through the resident
    path: prefill-with-frontend writes the projected cross KV rows as a
    delta into the active slots; decode reads them back — both
    bit-identical to the gather/scatter composition."""
    from repro.config import ModelConfig
    cfg = ModelConfig(name="tiny-cross", family="dense", n_layers=2,
                      d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
                      d_ff=128, vocab=50, tie_embeddings=True,
                      dtype="float32", cross_attn_period=2,
                      n_frontend_tokens=4)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    pool = M.init_cache(cfg, 4, MAX_LEN, dtype=jnp.float32)
    rng = np.random.default_rng(13)
    idx = jnp.asarray([1, 3], jnp.int32)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 6)), jnp.int32)
    fe = jnp.asarray(rng.normal(size=(2, cfg.n_frontend_tokens,
                                      cfg.d_model)) * 0.1, jnp.float32)

    # prefill with frontend: cross KV rows written in place
    lg_a, pool_a, _ = M.slot_extend(params, cfg, toks, pool, idx,
                                    frontend=fe)
    sub = M.gather_slots(pool, idx)
    lg_b, sub, _ = M.extend(params, cfg, toks, sub, frontend=fe)
    pool_b = M.scatter_slots(pool, sub, idx)
    np.testing.assert_array_equal(np.asarray(lg_a), np.asarray(lg_b))

    # decode without frontend: reads the slot-resident cross cache
    t2 = jnp.asarray(rng.integers(0, cfg.vocab, (2, 1)), jnp.int32)
    lg_a2, _, _ = M.slot_decode_step(params, cfg, t2, pool_a, idx)
    sub = M.gather_slots(pool_b, idx)
    lg_b2, _, _ = M.decode_step(params, cfg, t2, sub)
    np.testing.assert_array_equal(np.asarray(lg_a2), np.asarray(lg_b2))


def test_speculative_snapshot_rollback_after_inplace_steps(pair):
    """Snapshots taken from a cache advanced by in-place writes must
    still be pure copies: drafting on them never leaks into the resident
    cache, and discarding them is a complete rollback."""
    runner, ref, cfg = pair
    rng = np.random.default_rng(11)
    toks = rng.integers(0, cfg.vocab, 7)
    runner.prefill_request(0, toks)
    ref.prefill(0, toks)
    # advance the resident cache in place, then snapshot
    step = int(rng.integers(0, cfg.vocab))
    runner.decode([0], np.asarray([step]))
    ref.decode(0, step)
    snap = runner.speculative_caches([0])
    for t in rng.integers(0, cfg.vocab, 3):
        _, snap = runner.decode([0], np.asarray([t]), caches=snap)
    assert runner.length(0) == len(toks) + 1
    nxt = int(rng.integers(0, cfg.vocab))
    lg, _ = runner.decode([0], np.asarray([nxt]))
    np.testing.assert_allclose(lg[0], ref.decode(0, nxt), atol=ATOL)


def test_short_prompt_prefill_single_padded_chunk():
    """A 7-token prompt must prefill as ONE pad-and-mask slot_extend of
    bucket width 8 (chunked write-through, no 4+2+1 bucket loop) and the
    slot length must count only the real tokens."""
    cfg = _tiny("attn")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    runner = ModelRunner(cfg, params, max_len=MAX_LEN)
    calls = []
    orig_e = runner._jit_slot_extend
    runner._jit_slot_extend = lambda *a, **k: (
        calls.append(int(k["tokens"].shape[1])) or orig_e(*a, **k))
    rng = np.random.default_rng(3)
    toks = rng.integers(0, cfg.vocab, 7)
    lg, _ = runner.prefill_request(0, toks)
    assert calls == [8]
    ref = PerRequestReference(cfg, params)
    np.testing.assert_allclose(lg, ref.prefill(0, toks), atol=ATOL)
    assert runner.length(0) == 7


def _tiny_exotic(kind):
    """MLA / sliding-window tiny variants: the pad-and-mask write path
    must hold for the latent cache and the ring cache too."""
    from repro.config import MLAConfig
    common = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                  head_dim=16, d_ff=128, vocab=50, tie_embeddings=True,
                  dtype="float32")
    if kind == "mla":
        return ModelConfig(name="tiny-mla", family="dense", attention="mla",
                           mla=MLAConfig(q_lora_rank=32, kv_lora_rank=32,
                                         qk_nope_head_dim=16,
                                         qk_rope_head_dim=8, v_head_dim=16),
                           **common)
    return ModelConfig(name="tiny-swa", family="dense", attention="swa",
                       sliding_window=16, **common)


@pytest.mark.parametrize("kind", ["mla", "swa"])
def test_padded_chunk_prefill_exotic_attention(kind):
    cfg = _tiny_exotic(kind)
    params = M.init_params(jax.random.PRNGKey(2), cfg)
    runner = ModelRunner(cfg, params, max_len=MAX_LEN)
    ref = PerRequestReference(cfg, params)
    rng = np.random.default_rng(13)
    toks = rng.integers(0, cfg.vocab, 13)        # pads 13 -> 16
    lg, _ = runner.prefill_request(0, toks)
    np.testing.assert_allclose(lg, ref.prefill(0, toks), atol=ATOL)
    for t in rng.integers(0, cfg.vocab, 3):
        lg, _ = runner.decode([0], np.asarray([t]))
        np.testing.assert_allclose(lg[0], ref.decode(0, int(t)), atol=ATOL)


def test_padded_chunk_prefill_swa_prompt_past_ring_capacity():
    """A windowed config chunks prefill at RING_MARGIN: a prompt longer
    than the ring capacity (window + margin) must still be exact — a
    wider padded chunk would scatter pad columns onto keys still inside
    some query's window (regression: 300-token prompt, window 16)."""
    import jax.numpy as jnp
    cfg = _tiny_exotic("swa")                    # window 16, capacity 144
    params = M.init_params(jax.random.PRNGKey(2), cfg)
    runner = ModelRunner(cfg, params, max_len=512)
    rng = np.random.default_rng(4)
    toks = rng.integers(0, cfg.vocab, 300)
    lg, _ = runner.prefill_request(0, toks)
    cache = M.init_cache(cfg, 1, 512, dtype=jnp.float32)
    rlg, cache, _ = M.prefill(params, cfg, jnp.asarray(toks)[None], cache)
    np.testing.assert_allclose(lg, np.asarray(rlg[0, -1, :cfg.vocab]),
                               atol=ATOL)
    for t in rng.integers(0, cfg.vocab, 3):
        dl, _ = runner.decode([0], np.asarray([int(t)]))
        rl, cache, _ = M.decode_step(params, cfg, jnp.asarray([[int(t)]]),
                                     cache)
        np.testing.assert_allclose(dl[0], np.asarray(rl[0, 0, :cfg.vocab]),
                                   atol=ATOL)


@pytest.mark.parametrize("kind", ["attn", "ssm", "hybrid"])
@pytest.mark.parametrize("n", [1, 5, 8, 13])
def test_padded_chunk_prefill_matches_reference(kind, n):
    """Pad-and-mask prefill must be invisible: logits at the last real
    position and every subsequent decode step match the per-request
    reference exactly for attention KV, SSM recurrent/conv state and the
    hybrid mix (the masked tail writes nothing a read can see)."""
    cfg = _tiny(kind)
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    runner = ModelRunner(cfg, params, max_len=MAX_LEN)
    ref = PerRequestReference(cfg, params)
    rng = np.random.default_rng(7 + n)
    toks = rng.integers(0, cfg.vocab, n)
    lg, _ = runner.prefill_request(0, toks)
    np.testing.assert_allclose(lg, ref.prefill(0, toks), atol=ATOL)
    assert runner.length(0) == n
    # decoding after a masked prefill keeps matching: the pad rows were
    # never read and the next tokens overwrite their columns
    for t in rng.integers(0, cfg.vocab, 4):
        lg, _ = runner.decode([0], np.asarray([t]))
        np.testing.assert_allclose(lg[0], ref.decode(0, int(t)), atol=ATOL)
    assert runner.length(0) == n + 4


def test_slot_bucket_clamps_to_pow2():
    assert slot_bucket(1) == 1
    assert slot_bucket(3) == 4
    assert slot_bucket(256) == 256
    # past the enumerated buckets: next power of two, not raw n
    assert slot_bucket(257) == 512
    assert slot_bucket(300) == 512
    assert slot_bucket(512) == 512
    assert slot_bucket(513) == 1024


def test_slot_pool_growth_and_buckets():
    cfg = _tiny("attn")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    mgr = SlotCacheManager(cfg, MAX_LEN, n_slots=2)
    slots = [mgr.admit(r) for r in range(5)]        # forces two doublings
    assert len(set(slots)) == 5
    assert SlotCacheManager.SCRATCH not in slots
    assert mgr.n_slots == 8
    assert int(mgr.cache["lengths"].shape[0]) == mgr.n_slots + 1
    # bucketed index padding targets scratch
    idx = np.asarray(mgr.padded_idx([0, 1, 4]))
    assert idx.shape[0] == slot_bucket(3) == 4
    assert idx[-1] == SlotCacheManager.SCRATCH
    assert ModelRunner(cfg, params, max_len=MAX_LEN).slots is not mgr


def test_idx_memo_survives_admissions_and_selective_release():
    cfg = _tiny("attn")
    mgr = SlotCacheManager(cfg, MAX_LEN, n_slots=4)
    for r in (0, 1, 2):
        mgr.admit(r)
    idx01 = mgr.padded_idx([0, 1])
    idx2 = mgr.padded_idx([2])
    # admitting a new request must not evict hot decode-batch indices
    mgr.admit(7)
    assert mgr.padded_idx([0, 1]) is idx01
    assert mgr.padded_idx([2]) is idx2
    # releasing rid 1 drops only the batches that contained it
    mgr.release(1)
    assert (0, 1) not in mgr._idx_cache
    assert mgr.padded_idx([2]) is idx2
    # the freed slot re-issued to a new rid resolves correctly
    slot1 = mgr.admit(9)
    idx9 = np.asarray(mgr.padded_idx([9]))
    assert idx9[0] == slot1


def test_idx_memo_size_bounded():
    cfg = _tiny("attn")
    mgr = SlotCacheManager(cfg, MAX_LEN, n_slots=2)
    mgr.admit(0)
    mgr.admit(1)
    mgr.IDX_CACHE_MAX = 8
    for i in range(40):
        mgr.padded_idx([0] if i % 2 else [0, 1])
        mgr.padded_idx([1, 0] if i % 3 else [1])
        # unique keys: vary via tuple of repeated rids
        mgr.padded_idx([0] * (1 + i % 5))
    assert len(mgr._idx_cache) <= 8


def test_extend_snapshot_matches_decode_chain(pair):
    """Teacher-forcing a snapshot (draft-ahead warm-up) must land in the
    same state as decoding the same tokens one by one."""
    runner, ref, cfg = pair
    rng = np.random.default_rng(5)
    toks = rng.integers(0, cfg.vocab, 9)
    runner.prefill_request(0, toks)
    chain = rng.integers(0, cfg.vocab, 4).astype(np.int32)

    snap_a = runner.speculative_caches([0])
    for t in chain:
        lg_a, snap_a = runner.decode([0], np.asarray([t]), caches=snap_a)

    snap_b = runner.speculative_caches([0])
    lg_b, snap_b = runner.extend_snapshot(snap_b, chain[None, :])
    np.testing.assert_allclose(lg_a[0], lg_b[0], atol=ATOL)

    # and chaining continues identically from both states
    nxt = int(rng.integers(0, cfg.vocab))
    la, _ = runner.decode([0], np.asarray([nxt]), caches=snap_a)
    lb, _ = runner.decode([0], np.asarray([nxt]), caches=snap_b)
    np.testing.assert_allclose(la[0], lb[0], atol=ATOL)
