"""Slot-based runner equivalence: the slot-resident continuous-batching
cache engine (gather -> step -> scatter inside one jitted program) must
produce logits identical to the seed's per-request flow (independent
batch-1 caches) for prefill, decode, verify and extend — across
attention, SSM and hybrid families — including slot reuse after eviction
and slot-pool growth."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import TINY_MAX_LEN as MAX_LEN, tiny_model_cfg as _tiny
from repro.models import model as M
from repro.serving.runner import ModelRunner, SlotCacheManager, slot_bucket

ATOL = 1e-5


class PerRequestReference:
    """The seed cache-ownership model: one batch-1 cache pytree per
    request, stepped independently (what stack_caches/split_cache
    round-trips computed)."""

    def __init__(self, cfg, params):
        self.cfg, self.params = cfg, params
        self.caches = {}

    def prefill(self, rid, toks):
        cache = M.init_cache(self.cfg, 1, MAX_LEN, dtype=jnp.float32)
        lg, cache, _ = M.prefill(self.params, self.cfg,
                                 jnp.asarray(toks, jnp.int32)[None], cache)
        self.caches[rid] = cache
        return np.asarray(lg[0, -1, : self.cfg.vocab])

    def decode(self, rid, tok):
        lg, self.caches[rid], _ = M.decode_step(
            self.params, self.cfg, jnp.asarray([[tok]], jnp.int32),
            self.caches[rid])
        return np.asarray(lg[0, 0, : self.cfg.vocab])

    def verify(self, rid, toks, rel_pos, seg_mask):
        cache = self.caches[rid]
        positions = cache["lengths"][:, None] + jnp.asarray(rel_pos,
                                                            jnp.int32)[None]
        lg, _, _ = M.verify_chunk(
            self.params, self.cfg, jnp.asarray(toks, jnp.int32)[None], cache,
            positions=positions,
            seg_mask=jnp.asarray(seg_mask, bool)[None], write=False)
        return np.asarray(lg[0, :, : self.cfg.vocab])

    def extend(self, rid, toks):
        lg, self.caches[rid], _ = M.extend(
            self.params, self.cfg, jnp.asarray(toks, jnp.int32)[None],
            self.caches[rid])
        return np.asarray(lg[0, -1, : self.cfg.vocab])


@pytest.fixture(params=["attn", "ssm", "hybrid"])
def pair(request):
    cfg = _tiny(request.param)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    # n_slots=2 so the third admission exercises slot-pool growth
    return (ModelRunner(cfg, params, max_len=MAX_LEN, n_slots=2),
            PerRequestReference(cfg, params), cfg)


def test_prefill_decode_verify_extend_match(pair):
    runner, ref, cfg = pair
    rng = np.random.default_rng(0)
    rids = [0, 1, 2]
    for rid in rids:
        toks = rng.integers(0, cfg.vocab, 7 + 3 * rid)
        lg_s, _ = runner.prefill_request(rid, toks)
        np.testing.assert_allclose(lg_s, ref.prefill(rid, toks), atol=ATOL)

    # batched decode (bucket pads 3 -> 4 with scratch rows)
    step = rng.integers(0, cfg.vocab, 3)
    lg_s, _ = runner.decode(rids, step)
    for i, rid in enumerate(rids):
        np.testing.assert_allclose(lg_s[i], ref.decode(rid, step[i]),
                                   atol=ATOL)

    # chain verification (no commit): logits match, caches untouched
    G = 4
    vt = rng.integers(0, cfg.vocab, (3, G))
    rel = np.broadcast_to(np.arange(G, dtype=np.int32), (3, G))
    mask = np.broadcast_to(np.tril(np.ones((G, G), bool)), (3, G, G))
    lg_s = runner.verify(rids, vt, rel, mask)
    for i, rid in enumerate(rids):
        np.testing.assert_allclose(lg_s[i], ref.verify(rid, vt[i], rel[i],
                                                       mask[i]), atol=ATOL)

    # ragged commit: per-request token counts differ (grouped by length)
    commits = {0: [1, 2], 1: [3], 2: [4, 5, 6]}
    tails = runner.extend_committed(commits)
    for rid, toks in commits.items():
        np.testing.assert_allclose(tails[rid], ref.extend(rid, toks),
                                   atol=ATOL)
        assert runner.length(rid) == int(ref.caches[rid]["lengths"][0])


def test_slot_reuse_after_eviction(pair):
    runner, ref, cfg = pair
    rng = np.random.default_rng(1)
    for rid in (0, 1):
        toks = rng.integers(0, cfg.vocab, 8)
        runner.prefill_request(rid, toks)
        ref.prefill(rid, toks)
    evicted_slot = runner.slots.slot_of[1]
    runner.drop(1)

    # the freed slot must be reused and fully reset (no KV/state leakage
    # from the previous tenant)
    toks = rng.integers(0, cfg.vocab, 11)
    lg_s, _ = runner.prefill_request(9, toks)
    assert runner.slots.slot_of[9] == evicted_slot
    np.testing.assert_allclose(lg_s, ref.prefill(9, toks), atol=ATOL)

    # survivors and the new tenant still decode identically
    step = rng.integers(0, cfg.vocab, 2)
    lg_s, _ = runner.decode([0, 9], step)
    np.testing.assert_allclose(lg_s[0], ref.decode(0, step[0]), atol=ATOL)
    np.testing.assert_allclose(lg_s[1], ref.decode(9, step[1]), atol=ATOL)


def test_speculative_snapshot_is_rollback(pair):
    runner, ref, cfg = pair
    rng = np.random.default_rng(2)
    toks = rng.integers(0, cfg.vocab, 9)
    runner.prefill_request(0, toks)
    ref.prefill(0, toks)

    # draft on a snapshot: advances the snapshot, not the slot cache
    snap = runner.speculative_caches([0])
    for t in rng.integers(0, cfg.vocab, 3):
        _, snap = runner.decode([0], np.asarray([t]), caches=snap)
    assert runner.length(0) == len(toks)

    # the slot cache then commits from its pre-draft state
    step = int(rng.integers(0, cfg.vocab))
    lg_s, _ = runner.decode([0], np.asarray([step]))
    np.testing.assert_allclose(lg_s[0], ref.decode(0, step), atol=ATOL)


def test_slot_pool_growth_and_buckets():
    cfg = _tiny("attn")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    mgr = SlotCacheManager(cfg, MAX_LEN, n_slots=2)
    slots = [mgr.admit(r) for r in range(5)]        # forces two doublings
    assert len(set(slots)) == 5
    assert SlotCacheManager.SCRATCH not in slots
    assert mgr.n_slots == 8
    assert int(mgr.cache["lengths"].shape[0]) == mgr.n_slots + 1
    # bucketed index padding targets scratch
    idx = np.asarray(mgr.padded_idx([0, 1, 4]))
    assert idx.shape[0] == slot_bucket(3) == 4
    assert idx[-1] == SlotCacheManager.SCRATCH
    assert ModelRunner(cfg, params, max_len=MAX_LEN).slots is not mgr


def test_idx_memo_survives_admissions_and_selective_release():
    cfg = _tiny("attn")
    mgr = SlotCacheManager(cfg, MAX_LEN, n_slots=4)
    for r in (0, 1, 2):
        mgr.admit(r)
    idx01 = mgr.padded_idx([0, 1])
    idx2 = mgr.padded_idx([2])
    # admitting a new request must not evict hot decode-batch indices
    mgr.admit(7)
    assert mgr.padded_idx([0, 1]) is idx01
    assert mgr.padded_idx([2]) is idx2
    # releasing rid 1 drops only the batches that contained it
    mgr.release(1)
    assert (0, 1) not in mgr._idx_cache
    assert mgr.padded_idx([2]) is idx2
    # the freed slot re-issued to a new rid resolves correctly
    slot1 = mgr.admit(9)
    idx9 = np.asarray(mgr.padded_idx([9]))
    assert idx9[0] == slot1


def test_idx_memo_size_bounded():
    cfg = _tiny("attn")
    mgr = SlotCacheManager(cfg, MAX_LEN, n_slots=2)
    mgr.admit(0)
    mgr.admit(1)
    mgr.IDX_CACHE_MAX = 8
    for i in range(40):
        mgr.padded_idx([0] if i % 2 else [0, 1])
        mgr.padded_idx([1, 0] if i % 3 else [1])
        # unique keys: vary via tuple of repeated rids
        mgr.padded_idx([0] * (1 + i % 5))
    assert len(mgr._idx_cache) <= 8


def test_extend_snapshot_matches_decode_chain(pair):
    """Teacher-forcing a snapshot (draft-ahead warm-up) must land in the
    same state as decoding the same tokens one by one."""
    runner, ref, cfg = pair
    rng = np.random.default_rng(5)
    toks = rng.integers(0, cfg.vocab, 9)
    runner.prefill_request(0, toks)
    chain = rng.integers(0, cfg.vocab, 4).astype(np.int32)

    snap_a = runner.speculative_caches([0])
    for t in chain:
        lg_a, snap_a = runner.decode([0], np.asarray([t]), caches=snap_a)

    snap_b = runner.speculative_caches([0])
    lg_b, snap_b = runner.extend_snapshot(snap_b, chain[None, :])
    np.testing.assert_allclose(lg_a[0], lg_b[0], atol=ATOL)

    # and chaining continues identically from both states
    nxt = int(rng.integers(0, cfg.vocab))
    la, _ = runner.decode([0], np.asarray([nxt]), caches=snap_a)
    lb, _ = runner.decode([0], np.asarray([nxt]), caches=snap_b)
    np.testing.assert_allclose(la[0], lb[0], atol=ATOL)
