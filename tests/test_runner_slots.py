"""Slot-based runner equivalence: the slot-resident continuous-batching
cache engine (gather -> step -> scatter inside one jitted program) must
produce logits identical to the seed's per-request flow (independent
batch-1 caches) for prefill, decode, verify and extend — across
attention, SSM and hybrid families — including slot reuse after eviction
and slot-pool growth."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig, SSMConfig
from repro.models import model as M
from repro.serving.runner import ModelRunner, SlotCacheManager, slot_bucket

ATOL = 1e-5
MAX_LEN = 96


def _tiny(kind: str) -> ModelConfig:
    common = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                  head_dim=16, d_ff=128, vocab=50, tie_embeddings=True,
                  dtype="float32")
    if kind == "attn":
        return ModelConfig(name="tiny-attn", family="dense", **common)
    if kind == "ssm":
        return ModelConfig(name="tiny-ssm", family="ssm",
                           ssm=SSMConfig(d_state=16, head_dim=16,
                                         chunk_size=16), **common)
    return ModelConfig(name="tiny-hybrid", family="hybrid",
                       hybrid_attn_period=2, hybrid_attn_offset=1,
                       ssm=SSMConfig(d_state=16, head_dim=16, chunk_size=16),
                       **common)


class PerRequestReference:
    """The seed cache-ownership model: one batch-1 cache pytree per
    request, stepped independently (what stack_caches/split_cache
    round-trips computed)."""

    def __init__(self, cfg, params):
        self.cfg, self.params = cfg, params
        self.caches = {}

    def prefill(self, rid, toks):
        cache = M.init_cache(self.cfg, 1, MAX_LEN, dtype=jnp.float32)
        lg, cache, _ = M.prefill(self.params, self.cfg,
                                 jnp.asarray(toks, jnp.int32)[None], cache)
        self.caches[rid] = cache
        return np.asarray(lg[0, -1, : self.cfg.vocab])

    def decode(self, rid, tok):
        lg, self.caches[rid], _ = M.decode_step(
            self.params, self.cfg, jnp.asarray([[tok]], jnp.int32),
            self.caches[rid])
        return np.asarray(lg[0, 0, : self.cfg.vocab])

    def verify(self, rid, toks, rel_pos, seg_mask):
        cache = self.caches[rid]
        positions = cache["lengths"][:, None] + jnp.asarray(rel_pos,
                                                            jnp.int32)[None]
        lg, _, _ = M.verify_chunk(
            self.params, self.cfg, jnp.asarray(toks, jnp.int32)[None], cache,
            positions=positions,
            seg_mask=jnp.asarray(seg_mask, bool)[None], write=False)
        return np.asarray(lg[0, :, : self.cfg.vocab])

    def extend(self, rid, toks):
        lg, self.caches[rid], _ = M.extend(
            self.params, self.cfg, jnp.asarray(toks, jnp.int32)[None],
            self.caches[rid])
        return np.asarray(lg[0, -1, : self.cfg.vocab])


@pytest.fixture(params=["attn", "ssm", "hybrid"])
def pair(request):
    cfg = _tiny(request.param)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    # n_slots=2 so the third admission exercises slot-pool growth
    return (ModelRunner(cfg, params, max_len=MAX_LEN, n_slots=2),
            PerRequestReference(cfg, params), cfg)


def test_prefill_decode_verify_extend_match(pair):
    runner, ref, cfg = pair
    rng = np.random.default_rng(0)
    rids = [0, 1, 2]
    for rid in rids:
        toks = rng.integers(0, cfg.vocab, 7 + 3 * rid)
        lg_s, _ = runner.prefill_request(rid, toks)
        np.testing.assert_allclose(lg_s, ref.prefill(rid, toks), atol=ATOL)

    # batched decode (bucket pads 3 -> 4 with scratch rows)
    step = rng.integers(0, cfg.vocab, 3)
    lg_s, _ = runner.decode(rids, step)
    for i, rid in enumerate(rids):
        np.testing.assert_allclose(lg_s[i], ref.decode(rid, step[i]),
                                   atol=ATOL)

    # chain verification (no commit): logits match, caches untouched
    G = 4
    vt = rng.integers(0, cfg.vocab, (3, G))
    rel = np.broadcast_to(np.arange(G, dtype=np.int32), (3, G))
    mask = np.broadcast_to(np.tril(np.ones((G, G), bool)), (3, G, G))
    lg_s = runner.verify(rids, vt, rel, mask)
    for i, rid in enumerate(rids):
        np.testing.assert_allclose(lg_s[i], ref.verify(rid, vt[i], rel[i],
                                                       mask[i]), atol=ATOL)

    # ragged commit: per-request token counts differ (grouped by length)
    commits = {0: [1, 2], 1: [3], 2: [4, 5, 6]}
    tails = runner.extend_committed(commits)
    for rid, toks in commits.items():
        np.testing.assert_allclose(tails[rid], ref.extend(rid, toks),
                                   atol=ATOL)
        assert runner.length(rid) == int(ref.caches[rid]["lengths"][0])


def test_slot_reuse_after_eviction(pair):
    runner, ref, cfg = pair
    rng = np.random.default_rng(1)
    for rid in (0, 1):
        toks = rng.integers(0, cfg.vocab, 8)
        runner.prefill_request(rid, toks)
        ref.prefill(rid, toks)
    evicted_slot = runner.slots.slot_of[1]
    runner.drop(1)

    # the freed slot must be reused and fully reset (no KV/state leakage
    # from the previous tenant)
    toks = rng.integers(0, cfg.vocab, 11)
    lg_s, _ = runner.prefill_request(9, toks)
    assert runner.slots.slot_of[9] == evicted_slot
    np.testing.assert_allclose(lg_s, ref.prefill(9, toks), atol=ATOL)

    # survivors and the new tenant still decode identically
    step = rng.integers(0, cfg.vocab, 2)
    lg_s, _ = runner.decode([0, 9], step)
    np.testing.assert_allclose(lg_s[0], ref.decode(0, step[0]), atol=ATOL)
    np.testing.assert_allclose(lg_s[1], ref.decode(9, step[1]), atol=ATOL)


def test_speculative_snapshot_is_rollback(pair):
    runner, ref, cfg = pair
    rng = np.random.default_rng(2)
    toks = rng.integers(0, cfg.vocab, 9)
    runner.prefill_request(0, toks)
    ref.prefill(0, toks)

    # draft on a snapshot: advances the snapshot, not the slot cache
    snap = runner.speculative_caches([0])
    for t in rng.integers(0, cfg.vocab, 3):
        _, snap = runner.decode([0], np.asarray([t]), caches=snap)
    assert runner.length(0) == len(toks)

    # the slot cache then commits from its pre-draft state
    step = int(rng.integers(0, cfg.vocab))
    lg_s, _ = runner.decode([0], np.asarray([step]))
    np.testing.assert_allclose(lg_s[0], ref.decode(0, step), atol=ATOL)


def test_slot_pool_growth_and_buckets():
    cfg = _tiny("attn")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    mgr = SlotCacheManager(cfg, MAX_LEN, n_slots=2)
    slots = [mgr.admit(r) for r in range(5)]        # forces two doublings
    assert len(set(slots)) == 5
    assert SlotCacheManager.SCRATCH not in slots
    assert mgr.n_slots == 8
    assert int(mgr.cache["lengths"].shape[0]) == mgr.n_slots + 1
    # bucketed index padding targets scratch
    idx = np.asarray(mgr.padded_idx([0, 1, 4]))
    assert idx.shape[0] == slot_bucket(3) == 4
    assert idx[-1] == SlotCacheManager.SCRATCH
    assert ModelRunner(cfg, params, max_len=MAX_LEN).slots is not mgr
