"""Divisibility-aware sharding rules (DESIGN.md §6).

Rules map parameter/cache pytree paths to PartitionSpecs:

  train  — FSDP on "data" (weight matrices sharded on their non-TP dim),
           tensor parallel on "model", "pod" = extra data parallelism.
  serve  — tensor parallel on "model"; experts expert-parallel on "data"
           when the expert count divides it; batch ("pod","data") on
           activations and KV caches.

A dim is sharded on an axis only when divisible — otherwise the rule
degrades to replication on that axis (e.g. qwen1.5-4b's 20 heads, whisper's
12 heads, qwen2-moe's 60 experts). Head-count nondivisibility is recovered
where the *flattened* projection dim divides the axis.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, SequenceKey

from repro.config import ModelConfig
from repro.models import model as M


def _path_names(path):
    names = []
    for p in path:
        if isinstance(p, DictKey):
            names.append(str(p.key))
        elif isinstance(p, SequenceKey):
            names.append(f"#{p.idx}")
    return names


def _div(size: int, mesh, axis: Optional[str]):
    """axis if it divides size else None."""
    if axis is None or axis not in mesh.axis_names:
        return None
    return axis if size % mesh.shape[axis] == 0 else None


def _leaf_spec(names, shape, mesh, mode: str, moe_axis: str = "data",
               cfg=None, head_align: bool = False):
    """PartitionSpec for one param leaf (pre-stacking shape)."""
    name = names[-1]
    fsdp = "data" if mode == "train" else None
    tp = "model"

    def d(i, axis):  # shard dim i on axis if divisible
        return _div(shape[i], mesh, axis)

    def d_heads(i, axis, n_heads):
        """shard dim i only when whole heads land on each shard — slicing a
        head across shards makes every score einsum a partial-sum
        all-reduce of the full (B,T,H,S) tensor (§Perf H-align)."""
        if head_align and cfg is not None and axis in mesh.axis_names                 and n_heads % mesh.shape[axis] != 0:
            return None
        return d(i, axis)

    if name == "embed":
        return P(d(0, tp), d(1, fsdp))
    if name == "head":
        return P(d(0, fsdp), d(1, tp))
    if name == "pos":
        return P(None, None)
    if name == "wq":
        nh = cfg.n_heads if cfg else 0
        return P(d(0, fsdp), d_heads(1, tp, nh))
    if name in ("wk", "wv"):
        nh = cfg.n_kv_heads if cfg else 0
        return P(d(0, fsdp), d_heads(1, tp, nh))
    if name == "wo":
        nh = cfg.n_heads if cfg else 0
        return P(d_heads(0, tp, nh), d(1, fsdp))
    if name in ("wg", "wu", "wi"):
        return P(d(0, fsdp), d(1, tp))
    if name in ("wd",):
        return P(d(0, tp), d(1, fsdp))
    if name == "router":
        return P(d(0, fsdp), None)
    if name in ("w_gate", "w_up"):
        if moe_axis == "model":
            # expert parallelism on the TP axis: tokens are replicated
            # across "model", so each shard runs its local experts and the
            # combine is a small all-reduce (§Perf H2)
            return P(d(0, "model"), d(1, fsdp), None)
        ep = d(0, "data")
        return P(ep, d(1, fsdp) if ep is None else None, d(2, tp))
    if name == "w_down":
        if moe_axis == "model":
            return P(d(0, "model"), None, d(2, fsdp))
        ep = d(0, "data")
        return P(ep, d(1, tp), d(2, fsdp) if ep is None else None)
    # --- MLA ---
    if name == "wdq":
        return P(d(0, fsdp), None)
    if name == "wuq":
        return P(d(0, fsdp), d(1, tp))
    if name == "wdkv":
        return P(d(0, fsdp), None)
    if name == "wkr":
        return P(d(0, fsdp), None)
    if name in ("wuk", "wuv"):
        return P(d(0, fsdp), d(1, tp))
    # --- SSM (baseline: FSDP only; TP for SSD is a hillclimb lever) ---
    if name == "in_proj":
        return P(d(0, fsdp), None)
    if name == "out_proj":
        return P(None, d(1, fsdp))
    if name == "proj":  # mtp projection
        return P(d(0, fsdp), d(1, tp))
    # everything else (norms, biases, conv, A_log, dt_bias, ...): replicate
    return P()


def _is_stacked(names) -> bool:
    """Stage-stacked leaves carry a leading (repeats,) dim."""
    if names and names[0] == "stages":
        return True
    if "encoder" in names and "stage" in names:
        return True
    return False


def param_specs(cfg: ModelConfig, mesh, mode: str = "train",
                moe_axis: str = "data", head_align: bool = False):
    """Pytree of PartitionSpec matching init_params(cfg) structure."""
    shapes = jax.eval_shape(lambda k: M.init_params(k, cfg),
                            jax.random.PRNGKey(0))

    def spec(path, leaf):
        names = _path_names(path)
        shape = leaf.shape
        if _is_stacked(names):
            base = _leaf_spec(names, shape[1:], mesh, mode, moe_axis,
                              cfg, head_align)
            return P(None, *base)
        return _leaf_spec(names, shape, mesh, mode, moe_axis, cfg, head_align)

    return jax.tree_util.tree_map_with_path(spec, shapes)


# ---------------------------------------------------------------- caches

def _cache_leaf_spec(names, shape, mesh, cfg: ModelConfig, batch_axes,
                     kv_shard: str = "auto"):
    """Cache leaves are stage-stacked: (reps, B, ...)."""
    name = names[-1]
    bax = batch_axes if shape[1] % _axes_size(mesh, batch_axes) == 0 else None
    if name == "lengths":
        return P(bax)
    if name in ("k", "v"):
        hkv, hd = shape[3], shape[4]
        if kv_shard == "seq" and _div(shape[2], mesh, "model"):
            # sequence-parallel KV (flash-decoding partial merge — §Perf)
            return P(None, bax, "model", None, None)
        # (reps, B, C, Hkv, D): heads on model if divisible, else head_dim
        if _div(hkv, mesh, "model"):
            return P(None, bax, None, "model", None)
        if _div(hd, mesh, "model"):
            return P(None, bax, None, None, "model")
        return P(None, bax, None, None, None)
    if name in ("k_scale", "v_scale"):
        hkv = shape[3]
        if kv_shard == "seq" and _div(shape[2], mesh, "model"):
            return P(None, bax, "model", None)
        if _div(hkv, mesh, "model"):
            return P(None, bax, None, "model")
        return P(None, bax, None, None)
    if name == "slot_pos":
        if kv_shard == "seq" and _div(shape[2], mesh, "model"):
            return P(None, bax, "model")
        return P(None, bax, None)
    if name == "ssm":
        h = shape[2]
        return P(None, bax, _div(h, mesh, "model"), None, None)
    if name == "conv":
        return P(None, bax, None, None)
    if name == "pos":
        return P(None, bax)
    return P()


def _axes_size(mesh, axes):
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def cache_specs(cfg: ModelConfig, mesh, batch: int, max_len: int,
                dtype=jnp.bfloat16, kv_shard: str = "auto"):
    """(shapes, specs) for the decode cache of (cfg, batch, max_len).
    kv_shard: "auto" (heads, then head_dim) | "seq" (capacity dim on
    "model" — pair with cfg.decode_attn == "parallel")."""
    shapes = jax.eval_shape(
        lambda: M.init_cache(cfg, batch, max_len, dtype=dtype))
    bax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bax = bax if batch % _axes_size(mesh, bax) == 0 else (
        ("data",) if batch % mesh.shape["data"] == 0 else None)

    def spec(path, leaf):
        names = _path_names(path)
        if names[-1] == "lengths" and len(names) == 1:
            return P(bax if bax and leaf.shape[0] % _axes_size(mesh, bax) == 0
                     else None)
        return _cache_leaf_spec(names, leaf.shape, mesh, cfg, bax, kv_shard)

    return shapes, jax.tree_util.tree_map_with_path(spec, shapes)


def to_named(tree_specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_spec(mesh, global_batch: int):
    """PartitionSpec axis tuple for the batch dim of activations/tokens."""
    bax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if global_batch % _axes_size(mesh, bax) == 0:
        return bax
    if global_batch % mesh.shape["data"] == 0:
        return ("data",)
    return None
