"""Model/system configuration for the repro framework.

One `ModelConfig` describes every assigned architecture family:
dense / MoE / MLA / SSM / hybrid / enc-dec (audio) / VLM cross-attention.
All configs are frozen dataclasses so they hash and can key jit caches.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

VOCAB_PAD_MULTIPLE = 256  # vocab padded so unembedding shards on any mesh axis


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration (shared + routed, top-k)."""
    n_routed: int
    top_k: int
    d_ff: int                      # per-routed-expert hidden width
    n_shared: int = 0              # number of shared (always-on) experts
    shared_d_ff: int = 0           # total hidden width of shared experts (0 -> n_shared*d_ff)
    layer_offset: int = 0          # first layer index that is MoE
    layer_period: int = 1          # every `period`-th layer (from offset) is MoE
    router_aux_coef: float = 0.001  # load-balance aux loss coefficient

    def is_moe_layer(self, idx: int) -> bool:
        return idx >= self.layer_offset and (idx - self.layer_offset) % self.layer_period == 0

    @property
    def shared_width(self) -> int:
        return self.shared_d_ff if self.shared_d_ff else self.n_shared * self.d_ff


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 multi-head latent attention dimensions."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    @property
    def qk_head_dim(self) -> int:
        return self.qk_nope_head_dim + self.qk_rope_head_dim

    @property
    def cache_dim(self) -> int:
        # compressed KV latent + decoupled rope key, per token per layer
        return self.kv_lora_rank + self.qk_rope_head_dim


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) mixer configuration."""
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 128

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    # attention flavour
    attention: str = "full"        # full | swa | mla | none
    sliding_window: int = 0        # >0 with attention=="swa"
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    # block flavour
    norm_type: str = "rms"         # rms | layer
    mlp_type: str = "swiglu"       # swiglu | gelu
    pos_embed: str = "rope"        # rope | learned | none
    max_position: int = 0          # for learned pos embeds (0 -> unused)
    # sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (jamba): layer i is attention iff i % period == offset, else SSM
    hybrid_attn_period: int = 0
    hybrid_attn_offset: int = 0
    # vlm: layer i has cross-attention iff i % period == offset
    cross_attn_period: int = 0
    cross_attn_offset: int = 0
    n_frontend_tokens: int = 0     # stubbed modality tokens (audio frames / patches)
    frontend_dim: int = 0          # embedding dim supplied by the stub (0 -> d_model)
    # enc-dec (whisper): decoder config is `self`; encoder described here
    encoder_layers: int = 0
    encoder_seq: int = 0
    # extras
    tie_embeddings: bool = False
    mtp: bool = False              # DeepSeek multi-token-prediction head (depth 1)
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # long-context variant: "none" (full attn as configured) | "swa" override
    long_context: str = "none"
    long_context_window: int = 8192
    # decode attention path: "scan" (sequential KV blocks — baseline) |
    # "parallel" (flash-decoding parallel partials; enables sequence-
    # parallel KV sharding — §Perf optimization)
    decode_attn: str = "scan"
    # KV cache dtype: "bf16" | "int8" (quantized serving caches — §Perf)
    kv_dtype: str = "bf16"
    # weight-only quantization (models/quantize.py, DESIGN.md §2.9):
    # "" (inherit the pool default, CoSineConfig.drafter_quant) | "none"
    # | "int8" (per-output-channel symmetric int8 dense/embed weights,
    # calibrated from the trained checkpoint and swapped at load).
    # Orthogonal to kv_dtype, which quantizes cache *activations*.
    quant: str = ""
    # KV block size for cached attention (0 -> 1024); with seq-parallel KV
    # set this to capacity / mesh_model so block boundaries = shard
    # boundaries (no resharding)
    decode_block: int = 0
    # MoE dispatch: "auto" (GSPMD decides — gathers expert weights when
    # tokens are data-sharded) | "gather_tokens" (constrain the token rows
    # replicated so each data shard runs its local experts over all tokens
    # and results reduce-scatter back — §Perf H2)
    moe_dispatch: str = "auto"

    # ---------------- derived ----------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = VOCAB_PAD_MULTIPLE
        return ((self.vocab + m - 1) // m) * m

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def has_attention(self) -> bool:
        return self.attention != "none" or self.hybrid_attn_period > 0

    def layer_kind(self, idx: int) -> str:
        """'attn' or 'ssm' mixer for layer idx."""
        if self.family == "ssm":
            return "ssm"
        if self.hybrid_attn_period:
            return "attn" if idx % self.hybrid_attn_period == self.hybrid_attn_offset else "ssm"
        return "attn"

    def is_cross_layer(self, idx: int) -> bool:
        if not self.cross_attn_period:
            return False
        return idx % self.cross_attn_period == self.cross_attn_offset

    def is_moe_layer(self, idx: int) -> bool:
        return self.moe is not None and self.moe.is_moe_layer(idx)

    def with_overrides(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """Reduced variant of the same family for CPU smoke tests.

        <=2 scan blocks, d_model<=256, <=4 routed experts, small vocab.
        Structural features (MoE/MLA/SSM/hybrid/cross/enc-dec) preserved.
        """
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        head_dim = 64
        kw = dict(
            name=self.name + "-smoke",
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            max_position=min(self.max_position, 512) if self.max_position else 0,
            n_frontend_tokens=min(self.n_frontend_tokens, 16) if self.n_frontend_tokens else 0,
            frontend_dim=0,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=min(self.encoder_seq, 16) if self.encoder_seq else 0,
        )
        # keep the layer-pattern period intact; use 2 pattern blocks
        period = 1
        if self.hybrid_attn_period:
            period = max(period, self.hybrid_attn_period)
        if self.cross_attn_period:
            period = max(period, self.cross_attn_period)
        if self.moe is not None:
            period = max(period, self.moe.layer_period)
        n_layers = max(2, 2 * period)
        if self.moe is not None and self.moe.layer_offset:
            n_layers = max(n_layers, self.moe.layer_offset + 2 * self.moe.layer_period)
        kw["n_layers"] = n_layers
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe,
                n_routed=min(self.moe.n_routed, 4),
                top_k=min(self.moe.top_k, 2),
                d_ff=min(self.moe.d_ff, 256),
                n_shared=min(self.moe.n_shared, 1),
                shared_d_ff=min(self.moe.shared_d_ff, 256) if self.moe.shared_d_ff else 0,
            )
        if self.mla is not None:
            kw["mla"] = MLAConfig(q_lora_rank=64, kv_lora_rank=64,
                                  qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32)
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(self.ssm, d_state=32, head_dim=32, chunk_size=16)
        return self.with_overrides(**kw)


@dataclass(frozen=True)
class InputShape:
    """One assigned (seq_len, global_batch) workload."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


# ---------------- speculative-inference system config ----------------

@dataclass(frozen=True)
class CoSineConfig:
    """CoSine system knobs (paper §4)."""
    n_drafters: int = 4
    draft_len: int = 5             # gamma: draft tokens per iteration
    drafters_per_request: int = 2  # paper: 2-3 drafters selected per request
    tree_width: int = 2            # branches retained when building the token tree
    # routing (Eq. 3)
    tau: float = 2.0               # acceptance-length threshold for exploration
    alpha: float = 0.5             # exploration coefficient (alpha > beta)
    beta: float = 0.9              # exploitation coefficient
    routing_ema: float = 0.8       # EMA over historical routing scores
    # scheduler (Eq. 5-8)
    gamma_max_total: int = 64      # Gamma_max: verified-token budget per batch
    t_max_ms: float = 1e9          # latency SLO
    m_max_bytes: float = 1e15      # memory budget
    lam: float = 0.0015            # lambda: latency/throughput trade-off weight
    max_batch: int = 16
    # adaptive speculation (Alg. 2)
    min_gamma: int = 1
    gamma_max: int = 16            # hard per-request draft-length ceiling
    #                                (balance_gamma / feedback growth cap)
    # lambda feedback conditioning (scheduler.effective_lam): the
    # observation multipliers (queue pressure, starved verifier, hot
    # drafter) compose multiplicatively; the composed multiplier is
    # clamped to [lam_mult_min, lam_mult_max] so feedback can never
    # drive the effective lambda to extremes, and a deadband around the
    # busy-fraction thresholds keeps it from oscillating when a stage
    # hovers at its setpoint
    lam_mult_min: float = 0.25
    lam_mult_max: float = 8.0
    lam_deadband: float = 0.05
    # backlog aging (starvation freedom): each ms a request has waited
    # shrinks its effective context length by this many tokens in the
    # scheduler's sort key, so long-context requests age past the
    # candidate bound instead of starving behind a stream of short ones
    age_tok_per_ms: float = 0.05
    # priority classes: smaller is more urgent (0 = high, 1 = normal,
    # 2 = low); a class step is worth this much queue age in the sort key
    priority_age_bonus_ms: float = 2000.0
    # --- SLO-aware admission control (DESIGN.md §2.5) ---
    enable_admission: bool = False
    default_slo_ms: float = float("inf")  # per-request deadline budget
    #                                       (deadline = arrival + slo)
    admit_queue_cap: int = 0       # >0: max cold backlog under saturation
    #                                before the overflow is shed
    shed_when_late: bool = True    # shed queued zero-token requests that
    #                                can no longer meet their deadline
    #                                (only while the verifier saturates)
    preempt_priority: bool = True  # urgent arrivals evict the slots of
    #                                lower-priority in-flight requests
    #                                (slot evict / re-admit path)
    slo_trim: bool = True          # SpecServe-style per-request gamma
    #                                trimming when SLO headroom shrinks
    # multi-node drafter cluster (DESIGN.md §2.4)
    cut_pace_slack: float = 1.6    # fused lock-step window vs fastest node
    straggler_grace_frac: float = 0.25  # grace (frac of fused draft time)
    #                                     for late chains to join as side
    #                                     branches before being dropped
    conf_gate: float = 0.65        # fused confidence below which dispatch
    #                                waits the grace window for side chains
    straggler_policy: str = "side"  # "side" (late chains -> tree side
    #                                 branches) | "drop" (discard)
    straggler_penalty: float = 0.5  # router down-weight on chronically
    #                                 late nodes (Eq. 3 exploration)
    # route-faithful drafting (DESIGN.md §2.4): each drafter decodes only
    # the requests routed to it (its sub-batch), so drafter compute scales
    # with sum(|sub-batch|) ~= k*B rather than N*B. False restores the
    # legacy full fan-out (every node decodes the whole cohort) — kept for
    # the token-equivalence tests and as an explicit SpecInfer-style
    # ablation of the routing's compute saving.
    subbatch_drafting: bool = True
    # burst admission (DESIGN.md §2.7): batch several cold requests'
    # prompt forwards into one masked slot_extend write per model. Off
    # by default to keep the per-request prefill call order
    # byte-identical to the seed; the async backend always bursts (its
    # prefill queue naturally coalesces cold arrivals).
    batched_prefill: bool = False
    # ablation switches (paper §6.4)
    enable_routing: bool = True    # False -> random drafter selection
    enable_fusion: bool = True     # False -> independent per-drafter chains
    # observability (DESIGN.md §2.6): span tracing is cheap (simulated
    # clocks, no wall time) and on by default; obs_max_events > 0 ring-
    # bounds both the EventLog and the Tracer for long runs (oldest
    # entries drop; drop counts are surfaced in the metrics export)
    enable_tracing: bool = True
    obs_max_events: int = 0
    # --- paged KV/SSM pool (DESIGN.md §2.8) ---
    # paged_pool=True swaps the reserved-capacity slot cache (one
    # `bucket x max_len` row per resident request) for a fixed-size page
    # pool + per-request block tables: attention/MLA KV is allocated in
    # `page_size`-token pages on demand, reads gather only the pages a
    # request actually holds, and admission/eviction/rollback become
    # block-table operations. SSM state stays slot-indexed (it is O(1)
    # per request already). False (default) keeps the resident path
    # byte-identical to PR 8.
    paged_pool: bool = False
    page_size: int = 64            # tokens per KV page (must divide the
    #                                ring capacity of windowed layers)
    pool_pages: int = 0            # pages pre-allocated per model pool
    #                                (0 -> small auto size; the pool grows
    #                                by doubling when the free list empties)
    # --- weight-only drafter quantization (DESIGN.md §2.9) ---
    # pool-wide default for drafters whose ModelConfig.quant is ""
    # (unset): "none" keeps f32/bf16 weights, "int8" calibrates and
    # swaps per-output-channel int8 weights at engine construction.
    # A per-drafter ModelConfig.quant overrides this, so one pool can
    # run an int8 node beside bf16 nodes (configs/drafters.py).
    # Committed streams stay greedy-exact either way: only drafter
    # proposals change, never the target's accept/correct walk.
    drafter_quant: str = "none"
