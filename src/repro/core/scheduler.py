"""Collaborative pipeline component (paper §4.3, Eq. (5)–(8), Alg. 2):
batch assignment + adaptive speculation control.

The batch assignment problem (Eq. 8) — minimize T_ttl/b + lambda*Gamma
subject to the token budget (Eq. 6), latency SLO and memory cap (Eq. 7) —
is a small 0/1 program re-solved every iteration. We solve it the way the
paper's 0.1 ms "lightweight LP solver" does: candidate batches are prefixes
of the length-sorted request list (batched latency is dominated by the
longest member, so optimal batches are length-contiguous), with
AdaptiveSpeculation trimming per-request draft counts gamma_i to the
budget (Alg. 2 lines 17–20).

Under the decoupled executor (DESIGN.md §2) the scheduler additionally
sees the pipeline's *measured* state: a `PipelineObservation` carries the
verify-queue depth and the busy fractions of both stages as observed on
the event timeline, and `update_gamma_feedback` consumes that observed
verifier occupancy instead of an analytic busy ratio. The `t_ttl`
estimate inside `plan()` remains analytic — it is a planning heuristic;
the executor measures what actually happens.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.config import CoSineConfig
from repro.core.latency_model import LatencyModel
from repro.core.request_pool import Request
from repro.obs.metrics import DecisionLog


@dataclass
class PipelineObservation:
    """Measured executor state fed back into planning (DESIGN.md §2.3).

    verify_busy_frac / draft_busy_frac: busy time over active span,
    measured from the event timeline (not the analytic model).
    queue_depth: drafted cohorts waiting for the verification server.
    backlog: admitted requests the scheduler has not yet placed.
    drafter_busy_fracs / drafter_wait_fracs: per-drafter-node occupancy
    and queue-wait (time jobs sat waiting for the node, as a fraction of
    its active span), measured off each node's stage clock (DESIGN.md
    §2.4) — empty tuples under the coupled baselines.
    """
    verify_busy_frac: float = 1.0
    draft_busy_frac: float = 1.0
    queue_depth: int = 0
    backlog: int = 0
    drafter_busy_fracs: Tuple[float, ...] = ()
    drafter_wait_fracs: Tuple[float, ...] = ()
    # drafting can no longer cover verification even at the per-request
    # gamma ceiling (balance_gamma hit cfg.gamma_max): the pipeline is
    # verify-bound no matter how much is drafted, so feedback must not
    # discount lambda to "draft more"
    spec_saturated: bool = False

    @property
    def saturated(self) -> bool:
        """Verifier saturation signal the admission layer keys on:
        drafted work already queued at the server, or the verify stage
        essentially never idle."""
        return self.queue_depth > 0 or self.verify_busy_frac > 0.95

    @property
    def hottest_drafter_frac(self) -> float:
        """Occupancy of the most saturated drafter node (falls back to
        the aggregate when per-node data is unavailable)."""
        return max(self.drafter_busy_fracs, default=self.draft_busy_frac)

    @property
    def max_drafter_wait_frac(self) -> float:
        """Worst chronic queueing across the drafter nodes."""
        return max(self.drafter_wait_fracs, default=0.0)


def adaptive_speculation(gammas: List[int], gamma_max_total: int,
                         min_gamma: int = 1) -> List[int]:
    """Alg. 2 AdaptiveSpeculation: while sum gamma_i exceeds Gamma_max,
    decrement the largest gamma_j (never below min_gamma)."""
    g = list(gammas)
    while sum(g) > gamma_max_total:
        j = int(np.argmax(g))
        if g[j] <= min_gamma:
            break
        g[j] -= 1
    return g


@dataclass
class BatchPlan:
    requests: List[Request]
    gammas: List[int]
    t_ssm_ms: float
    t_llm_ms: float
    t_ttl_ms: float
    objective: float

    @property
    def big_gamma(self) -> int:
        return sum(self.gammas)


class RequestScheduler:
    def __init__(self, cfg: CoSineConfig, lat: LatencyModel,
                 mem_per_token_bytes: float = 0.0,
                 decisions: Optional[DecisionLog] = None):
        self.cfg = cfg
        self.lat = lat
        self.mem_per_token = mem_per_token_bytes
        # controller decision log (DESIGN.md §2.6): every λ-multiplier
        # update, SLO trim, balance cap and feedback step is recorded
        # with its inputs so feedback behaviour is auditable
        self.decisions = decisions
        # set by balance_gamma: drafting cannot cover verification even
        # at cfg.gamma_max (surfaced via PipelineObservation)
        self.spec_saturated = False

    def balance_gamma(self, b: int, l: int, n_drafters: int = 1,
                      now_ms: float = 0.0) -> int:
        """Pipeline-balancing draft length: smallest gamma whose drafting
        time covers the verification time (keeps the verifier busy without
        over-drafting — the adaptive speculation control signal).

        Capped at cfg.gamma_max: when drafting never covers verification
        (a fast cluster against a slow server) there is no balancing
        gamma, and over-drafting past the per-request ceiling would only
        inflate verification volume. The condition is remembered as
        `spec_saturated` and surfaced through `PipelineObservation` so
        feedback stops discounting lambda to "draft more"."""
        g_cap = max(self.cfg.gamma_max, self.cfg.min_gamma)
        for gamma in range(1, g_cap + 1):
            t_d = self.lat.t_ssm(b, l, gamma, n_drafters)
            t_v = self.lat.t_llm(b, l, b * gamma)
            if t_d >= t_v:
                self.spec_saturated = False
                if self.decisions is not None:
                    self.decisions.record(now_ms, "balance_gamma", b=b, l=l,
                                          gamma=gamma, saturated=False)
                return gamma
        self.spec_saturated = True
        if self.decisions is not None:
            self.decisions.record(now_ms, "balance_gamma", b=b, l=l,
                                  gamma=g_cap, saturated=True)
        return g_cap

    def effective_lam(self, observation: Optional[PipelineObservation],
                      now_ms: float = 0.0) -> float:
        """Observation-conditioned lambda for Eq. (8).

        Queue pressure raises it (trim speculation when drafted work is
        already waiting on the verifier); a starved verifier lowers it —
        but only while the backlog is shallow: with more waiting requests
        than a batch can hold, extra speculation per request would just
        delay them. A saturated (or chronically queued) drafter node
        while the verifier has slack means drafting is the bottleneck,
        so speculation is trimmed. The composed multiplier is clamped to
        [lam_mult_min, lam_mult_max] — the raw multipliers compose
        multiplicatively and would otherwise run away when both stages
        saturate — and a deadband below each busy-fraction threshold
        keeps the signal from flapping when a stage hovers at its
        setpoint."""
        cfg = self.cfg
        if observation is None:
            return cfg.lam
        dead = cfg.lam_deadband
        mult = 1.0 + observation.queue_depth
        if observation.verify_busy_frac < 0.8 - dead \
                and observation.backlog <= cfg.max_batch \
                and not observation.spec_saturated:
            mult *= 0.5                      # verifier starved: draft more
        if (observation.hottest_drafter_frac > 0.95
                or observation.max_drafter_wait_frac > 0.2) \
                and observation.verify_busy_frac < 0.95 - dead:
            mult *= 2.0                      # drafting is the bottleneck
        mult = min(max(mult, cfg.lam_mult_min), cfg.lam_mult_max)
        if self.decisions is not None:
            self.decisions.record(
                now_ms, "lam", mult=mult, lam=cfg.lam * mult,
                queue_depth=observation.queue_depth,
                backlog=observation.backlog,
                verify_busy_frac=observation.verify_busy_frac,
                hottest_drafter_frac=observation.hottest_drafter_frac,
                max_drafter_wait_frac=observation.max_drafter_wait_frac,
                spec_saturated=observation.spec_saturated)
        return cfg.lam * mult

    def slo_gamma(self, r: Request, now_ms: float,
                  pipelined: bool = True) -> int:
        """SpecServe-style per-request speculation trimming: the draft
        length an SLO-constrained request should run this iteration.

        With ample headroom this is just the request's adaptive gamma
        (capped at cfg.gamma_max). As the deadline approaches, the
        per-token latency budget shrinks; speculation deeper than the
        budget allows only adds drafting time ahead of each commit, so
        gamma is walked down until the estimated iteration time per
        committed token fits the remaining budget (never below
        min_gamma — an overdue request still speculates minimally)."""
        cfg = self.cfg
        g = min(r.gamma, cfg.gamma_max)
        # trimming never *raises* gamma — a request already below
        # min_gamma keeps its own value (plan must not exceed it)
        floor = min(cfg.min_gamma, g)
        if not cfg.slo_trim or r.deadline_ms == float("inf"):
            return g
        headroom = r.headroom_ms(now_ms)
        if headroom <= 0.0:
            if floor != g and self.decisions is not None:
                self.decisions.record(now_ms, "slo_gamma", rid=r.rid,
                                      gamma_from=g, gamma_to=floor,
                                      headroom_ms=headroom,
                                      budget_per_tok_ms=0.0)
            return floor
        remaining = max(r.max_new_tokens - len(r.generated), 1)
        budget_per_tok = headroom / remaining
        l = r.context_len
        exp_acc = max(r.l_acc_ema, 1.0)

        def ms_per_tok(g_: int) -> float:
            t_d = self.lat.t_ssm(1, l, g_) + self.lat.comm_ms
            t_v = self.lat.t_llm(1, l, g_)
            t_it = max(t_d, t_v) if pipelined else t_d + t_v
            # acceptance is bounded by the draft length (+1 correction)
            return t_it / min(exp_acc + 1.0, g_ + 1.0)

        g0 = g
        while g > floor and ms_per_tok(g) > budget_per_tok:
            g -= 1
        if g != g0 and self.decisions is not None:
            self.decisions.record(now_ms, "slo_gamma", rid=r.rid,
                                  gamma_from=g0, gamma_to=g,
                                  headroom_ms=headroom,
                                  budget_per_tok_ms=budget_per_tok)
        return g

    def plan(self, requests: Sequence[Request], pipelined: bool = True,
             n_drafters: int = 1, n_nodes: int = 0,
             observation: Optional[PipelineObservation] = None,
             extra_ctx: Optional[Dict[int, int]] = None,
             now_ms: float = 0.0) -> BatchPlan:
        """Solve Eq. (8) over aged-length-sorted prefixes.

        observation: measured pipeline state, folded into the effective
          lambda (see `effective_lam`).
        n_nodes: cluster size. With route-faithful sub-batching each of
          the n_nodes drafters decodes only its routed share, so the
          drafting estimate charges the expected per-node sub-batch
          ceil(b * n_drafters / n_nodes) instead of the cohort width —
          per-node load is real content now, and the plan's t_ssm must
          track the occupancy the hot-node trim acts on.
        extra_ctx: rid -> extra context tokens assumed beyond the
          committed state (draft-ahead plans against optimistic lengths).
        now_ms: planning time, for queue-age aging and SLO headroom.
          Candidates are ordered by *effective* length — context length
          minus an aging credit (age_tok_per_ms per waited ms, plus a
          priority-class bonus) — so a long-context request that has
          waited long enough sorts ahead of fresh short ones and cannot
          starve behind the 4*max_batch candidate bound (and, since the
          batch prefixes follow the same order, cannot be starved by the
          objective either). The critical length fed to the latency
          model stays the *real* max context of the batch.
        """
        cfg = self.cfg
        lam = self.effective_lam(observation, now_ms=now_ms)
        ctx_of = (lambda r: r.context_len + (extra_ctx or {}).get(r.rid, 0))

        def aged_len(r: Request) -> float:
            age = max(now_ms - r.arrival_ms, 0.0) \
                + cfg.priority_age_bonus_ms * (1 - r.priority)
            return ctx_of(r) - cfg.age_tok_per_ms * age

        def draft_b(b: int) -> int:
            if n_nodes > 1 and cfg.subbatch_drafting:
                return max(1, -(-b * min(n_drafters, n_nodes) // n_nodes))
            return b

        cand = sorted(requests,
                      key=lambda r: (aged_len(r), r.arrival_ms, r.rid))
        cand = cand[: 4 * cfg.max_batch]          # bound the search
        # SLO trimming is per-request, independent of the batch prefix —
        # computed once per plan (also keeps the decision log to one
        # entry per trimmed request, not one per candidate prefix)
        slo_of = {r.rid: self.slo_gamma(r, now_ms, pipelined) for r in cand}
        best: BatchPlan | None = None
        for b in range(1, min(len(cand), cfg.max_batch) + 1):
            sel = cand[:b]
            l = max(ctx_of(r) for r in sel)
            gam = adaptive_speculation(
                [slo_of[r.rid] for r in sel],
                cfg.gamma_max_total, cfg.min_gamma)
            big_g = sum(gam)
            t_ssm = self.lat.t_ssm(draft_b(b), l, max(gam), n_drafters)
            t_llm = self.lat.t_llm(b, l, big_g)
            t_ttl = (max(t_ssm + self.lat.comm_ms, t_llm) if pipelined
                     else t_ssm + self.lat.comm_ms + t_llm)
            if t_ttl > cfg.t_max_ms:
                continue
            mem = sum(ctx_of(r) + g for r, g in zip(sel, gam)) \
                * self.mem_per_token
            if mem > cfg.m_max_bytes:
                continue
            # Eq. (8): latency-per-request with a verified-token budget term.
            obj = t_ttl / b + lam * big_g
            plan = BatchPlan(sel, gam, t_ssm, t_llm, t_ttl, obj)
            if best is None or obj < best.objective:
                best = plan
        if best is None and cand:   # SLO-infeasible: serve the shortest alone
            r = cand[0]
            g = [max(self.cfg.min_gamma,
                     min(r.gamma, self.cfg.gamma_max,
                         self.cfg.gamma_max_total))]
            t_ssm = self.lat.t_ssm(draft_b(1), ctx_of(r), g[0], n_drafters)
            t_llm = self.lat.t_llm(1, ctx_of(r), g[0])
            best = BatchPlan([r], g, t_ssm, t_llm,
                             t_ssm + self.lat.comm_ms + t_llm, float("inf"))
        return best

    def update_gamma_feedback(self, request: Request, n_committed: int,
                              verifier_busy_frac: float,
                              now_ms: float = 0.0):
        """Alg. 2 adaptive control: grow gamma when the verifier has slack
        and drafts are being accepted; shrink when overloaded/rejected.

        Under the decoupled executor `verifier_busy_frac` is the measured
        occupancy of the verification stage (busy over busy+bubble, with
        queued cohorts pushing it above 1) — observed on the event
        timeline, not derived from the latency formulas. The coupled
        baselines still pass their analytic t_llm/t_iter ratio."""
        g0 = request.gamma
        if verifier_busy_frac < 0.8 and n_committed >= request.gamma:
            request.gamma = min(request.gamma + 1, self.cfg.gamma_max)
        elif verifier_busy_frac > 1.2 or n_committed <= 1:
            request.gamma = max(request.gamma - 1, self.cfg.min_gamma)
        if request.gamma != g0 and self.decisions is not None:
            self.decisions.record(now_ms, "gamma_feedback", rid=request.rid,
                                  gamma_from=g0, gamma_to=request.gamma,
                                  n_committed=n_committed,
                                  verifier_busy_frac=verifier_busy_frac)
