"""Request pool for continuous batching (paper §4.1/§4.3).

Requests live in the pool between iterations; the scheduler regroups a
batch every iteration (Alg. 2 line 3), so completions never stall the
pipeline and new arrivals join at the next iteration boundary.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                    # (P,) int32
    max_new_tokens: int
    domain: Optional[str] = None          # ground-truth domain (for eval only)
    arrival_ms: float = 0.0
    # --- SLO / admission (DESIGN.md §2.5) ---
    deadline_ms: float = float("inf")     # absolute SLO deadline
    priority: int = 1                     # class: 0 high, 1 normal, 2 low
    # --- mutable serving state ---
    generated: List[int] = field(default_factory=list)
    gamma: int = 4                        # current per-request draft length
    l_acc_ema: float = 0.0                # recent acceptance length (EMA)
    done: bool = False
    finish_ms: float = 0.0
    first_token_ms: float = -1.0
    shed_ms: float = -1.0                 # >= 0 once admission shed it
    n_preemptions: int = 0                # slot evictions by admission
    n_iterations: int = 0
    n_accepted_total: int = 0
    n_drafted_total: int = 0

    @property
    def context_len(self) -> int:
        return len(self.prompt) + len(self.generated)

    @property
    def was_shed(self) -> bool:
        return self.shed_ms >= 0.0

    @property
    def slo_met(self) -> bool:
        """Finished within its deadline (shed requests never meet it)."""
        return self.done and not self.was_shed \
            and self.finish_ms <= self.deadline_ms

    def headroom_ms(self, now_ms: float) -> float:
        """Remaining SLO budget (inf when no deadline was set)."""
        return self.deadline_ms - now_ms

    def record_acceptance(self, n_committed: int, gamma_used: int):
        self.n_iterations += 1
        self.n_accepted_total += n_committed
        self.n_drafted_total += gamma_used
        self.l_acc_ema = 0.7 * self.l_acc_ema + 0.3 * n_committed


class RequestPool:
    def __init__(self):
        self._requests: Dict[int, Request] = {}
        self._ids = itertools.count()
        self.completed: List[Request] = []
        self.shed: List[Request] = []
        self.n_submitted = 0

    def add(self, prompt, max_new_tokens: int, domain=None,
            arrival_ms: float = 0.0, deadline_ms: float = float("inf"),
            priority: int = 1) -> Request:
        rid = next(self._ids)
        r = Request(rid=rid, prompt=np.asarray(prompt, np.int32),
                    max_new_tokens=max_new_tokens, domain=domain,
                    arrival_ms=arrival_ms, deadline_ms=deadline_ms,
                    priority=priority)
        self._requests[rid] = r
        self.n_submitted += 1
        return r

    def get(self, rid: int) -> Optional[Request]:
        return self._requests.get(rid)

    def pending(self, now_ms: float = float("inf")) -> List[Request]:
        return [r for r in self._requests.values()
                if not r.done and r.arrival_ms <= now_ms]

    def finish(self, rid: int, now_ms: float):
        r = self._requests.pop(rid)
        r.done = True
        r.finish_ms = now_ms
        self.completed.append(r)

    def shed_request(self, rid: int, now_ms: float) -> Request:
        """Admission rejected the request: it leaves the pool whole —
        never half-committed (admission only sheds zero-token requests)
        — and is accounted on the `shed` list, so
        n_submitted == len(completed) + len(shed) + len(pool) always."""
        r = self._requests.pop(rid)
        assert not r.generated, "shedding a half-committed request"
        r.done = True
        r.shed_ms = now_ms
        r.finish_ms = now_ms
        self.shed.append(r)
        return r

    def __len__(self):
        return len(self._requests)

    @property
    def empty(self) -> bool:
        return not self._requests
