"""Draft token trees (paper §4.2, Fig. 5).

CoSine's cooperative generation produces, per request, a *fused main chain*
(the confidence-selected token x*_i at each depth) plus per-drafter *side
candidates* at each depth (the tokens the other drafters proposed, kept as
single-node branches — Eq. (4)'s dual dependency). The tree is linearized
into fixed-size arrays for one batched tree-attention verification pass.

Tree construction/acceptance is host-side numpy (this is the central
node's orchestration logic — microseconds); verification compute is the
batched JAX `verify_chunk` with the ancestor mask.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np


@dataclass
class TokenTree:
    """Linearized draft tree for one request.

    tokens[i], parent[i] (-1 = attaches to committed context), depth[i],
    prob[i] (drafter confidence), drafter[i] (proposing drafter id;
    -1 = fused main chain).
    Node 0..chain_len-1 is the fused main chain (parent i-1).
    """
    tokens: np.ndarray
    parent: np.ndarray
    depth: np.ndarray
    prob: np.ndarray
    drafter: np.ndarray
    chain_len: int

    @property
    def n_nodes(self) -> int:
        return len(self.tokens)

    def ancestor_mask(self) -> np.ndarray:
        """mask[i, j] = True iff j is an ancestor of i or j == i."""
        n = self.n_nodes
        m = np.eye(n, dtype=bool)
        for i in range(n):
            p = self.parent[i]
            while p >= 0:
                m[i, p] = True
                p = self.parent[p]
        return m


def build_tree(chain_tokens, chain_probs, side_tokens, side_probs,
               side_drafters, tree_width: int, max_nodes: int = 0) -> TokenTree:
    """Build the CoSine draft tree.

    chain_tokens/probs: (K,) fused main chain.
    side_tokens/probs/drafters: (K, N) per-depth per-drafter proposals
      (entries equal to the fused token are deduplicated away).
    tree_width: max side branches kept per depth (by confidence).
    """
    K = len(chain_tokens)
    toks: List[int] = list(map(int, chain_tokens))
    parent = list(range(-1, K - 1))
    depth = list(range(K))
    prob = list(map(float, chain_probs))
    drafter = [-1] * K

    for d in range(K):
        cand = {}
        for n in range(side_tokens.shape[1]):
            p = float(side_probs[d, n])
            if p < 0.0:
                # masked column (non-participant / dropped chain): its
                # token is not a proposal and must not leak into the
                # tree, even when fewer than tree_width real candidates
                # exist at this depth
                continue
            t = int(side_tokens[d, n])
            if t == int(chain_tokens[d]):
                continue
            if t not in cand or p > cand[t][0]:
                cand[t] = (p, int(side_drafters[d, n]))
        best = sorted(cand.items(), key=lambda kv: -kv[1][0])[: tree_width]
        for t, (p, dr) in best:
            toks.append(t)
            parent.append(d - 1)       # branches off the fused prefix
            depth.append(d)
            prob.append(p)
            drafter.append(dr)

    if max_nodes and len(toks) > max_nodes:
        # keep the main chain + highest-confidence side nodes
        side_idx = sorted(range(K, len(toks)), key=lambda i: -prob[i])
        keep = sorted(list(range(K)) + side_idx[: max_nodes - K])
        remap = {old: new for new, old in enumerate(keep)}
        toks = [toks[i] for i in keep]
        parent = [remap.get(parent[i], parent[i]) if parent[i] >= 0 else -1
                  for i in keep]
        depth = [depth[i] for i in keep]
        prob = [prob[i] for i in keep]
        drafter = [drafter[i] for i in keep]

    return TokenTree(tokens=np.asarray(toks, np.int32),
                     parent=np.asarray(parent, np.int32),
                     depth=np.asarray(depth, np.int32),
                     prob=np.asarray(prob, np.float32),
                     drafter=np.asarray(drafter, np.int32),
                     chain_len=K)


def chain_tree(tokens, probs=None, drafter: int = -1) -> TokenTree:
    """Degenerate tree = a single chain (vanilla speculation / SSM verify)."""
    K = len(tokens)
    probs = np.ones(K, np.float32) if probs is None else np.asarray(probs)
    return TokenTree(tokens=np.asarray(tokens, np.int32),
                     parent=np.arange(-1, K - 1, dtype=np.int32),
                     depth=np.arange(K, dtype=np.int32),
                     prob=probs.astype(np.float32),
                     drafter=np.full(K, drafter, np.int32),
                     chain_len=K)


def pad_trees(trees: List[TokenTree], n_nodes: int):
    """Batch trees into fixed arrays for one verification pass.

    Returns dict of np arrays:
      tokens (B, M), rel_pos (B, M) = depth, mask (B, M, M), valid (B, M).
    """
    B = len(trees)
    M = n_nodes
    tokens = np.zeros((B, M), np.int32)
    rel = np.zeros((B, M), np.int32)
    mask = np.zeros((B, M, M), bool)
    valid = np.zeros((B, M), bool)
    for b, t in enumerate(trees):
        n = min(t.n_nodes, M)
        tokens[b, :n] = t.tokens[:n]
        rel[b, :n] = t.depth[:n]
        mask[b, :n, :n] = t.ancestor_mask()[:n, :n]
        valid[b, :n] = True
    # padded nodes attend only to themselves (keeps softmax well-formed)
    for b in range(B):
        for i in range(M):
            if not valid[b, i]:
                mask[b, i, i] = True
    return {"tokens": tokens, "rel_pos": rel, "mask": mask, "valid": valid}


def accept_tree_greedy(tree: TokenTree, node_argmax: np.ndarray,
                       entry_argmax: int):
    """Greedy acceptance walk over the tree.

    node_argmax[i]: target argmax token AFTER node i's path.
    entry_argmax: target argmax for the first position (before any node).
    Returns (accepted_tokens list, accepted_node_ids list, correction_token).
    The output committed tokens = accepted + [correction]; losslessness:
    identical to incremental greedy decoding of the target.
    """
    children = {}
    for i in range(tree.n_nodes):
        children.setdefault(int(tree.parent[i]), []).append(i)

    path, path_tokens = [], []
    want = int(entry_argmax)          # token the target wants at this point
    cur = -1
    while True:
        nxt = None
        for c in children.get(cur, []):
            if int(tree.tokens[c]) == want:
                nxt = c
                break
        if nxt is None:
            break
        path.append(nxt)
        path_tokens.append(int(tree.tokens[nxt]))
        want = int(node_argmax[nxt])
        cur = nxt
    return path_tokens, path, want
