"""Adaptive request routing (paper §4.2, Eq. (1)–(3)).

Each request r keeps a routing vector M_r over drafters. After every
verification, the router folds in (a) the drafter's generation confidence
c_{n,i} and (b) the verification-aligned accuracy d_{n,i} (Eq. 1: cosine
similarity between target-embedding of the accepted token and of the
drafter's token, zero beyond the acceptance length), combined by the
normalized harmonic mean (Eq. 2) and EMA-smoothed. Routing (Eq. 3) mixes
top-score selection T(.) with random selection R(.), gated on the recent
acceptance length vs. threshold tau.

Evidence is participants-only: `update` folds in rows for the drafters
that actually drafted the request. Under route-faithful sub-batched
drafting (DESIGN.md §2.4) non-participant rows of the proposal matrices
hold no live tokens at all, so this is load-bearing, not just a
preference (property-tested in tests/test_subbatch.py). The routes this
class emits are likewise real content now — each selected node decodes
the request in its own sub-batch — so `node_lag`'s down-weighting and
the scheduler's hot-node trim act on true per-node occupancy.

Note (DESIGN.md): the paper states alpha > beta for exploration, which
would make exploration *more* greedy than exploitation; we implement the
evidently-intended semantics (exploration mode uses a lower top-scoring
fraction alpha < beta).
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

from repro.config import CoSineConfig


def cosine_sim(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    num = (a * b).sum(-1)
    den = np.linalg.norm(a, axis=-1) * np.linalg.norm(b, axis=-1)
    return num / np.maximum(den, 1e-9)


def verification_accuracy(embed: np.ndarray, drafter_tokens: np.ndarray,
                          accepted_tokens: Sequence[int]) -> np.ndarray:
    """Eq. (1). drafter_tokens: (K,) one drafter's proposals;
    accepted_tokens: the L_acc tokens the verifier committed.
    embed: (V, d) target embedding table (H(.)).
    Returns d (K,) in [0, 1] (cosine clipped at 0)."""
    K = len(drafter_tokens)
    L = min(len(accepted_tokens), K)
    d = np.zeros(K, np.float32)
    if L:
        ha = embed[np.asarray(accepted_tokens[:L], np.int32)]
        hd = embed[np.asarray(drafter_tokens[:L], np.int32)]
        d[:L] = np.clip(cosine_sim(ha, hd), 0.0, 1.0)
    return d


def routing_score(conf: np.ndarray, acc: np.ndarray) -> float:
    """Eq. (2): mean over positions of the normalized harmonic interaction
    c*d / (c*d + (1-c)(1-d)) — in (0, 1)."""
    c = np.clip(conf, 1e-6, 1 - 1e-6)
    d = np.clip(acc, 1e-6, 1 - 1e-6)
    num = c * d
    den = num + (1 - c) * (1 - d)
    return float(np.mean(num / den))


class AdaptiveRouter:
    """Maintains M (requests x drafters) and applies the Eq. (3) policy."""

    def __init__(self, n_drafters: int, cfg: CoSineConfig,
                 embed: np.ndarray, seed: int = 0):
        self.n = n_drafters
        self.cfg = cfg
        self.embed = embed
        self.rng = np.random.default_rng(seed)
        self.scores: Dict[int, np.ndarray] = {}
        # chronic-lateness EMA per drafter *node* (cluster feedback,
        # DESIGN.md §2.4): 0 = always on time, -> 1 = always cut. Both
        # the top-scoring order and the exploration draw of Eq. (3) are
        # down-weighted by it, so straggling nodes stop being selected
        # unless their routing score earns the extra latency.
        self.node_lag = np.zeros(n_drafters, np.float32)

    def vector(self, rid: int) -> np.ndarray:
        if rid not in self.scores:
            self.scores[rid] = np.full(self.n, 0.5, np.float32)
        return self.scores[rid]

    def set_prior(self, rid: int, drafter_logliks: Sequence[float]):
        """Content-based warm start (paper §5's pre-inference request
        analysis): initialize M_r from each drafter's likelihood of the
        prompt, z-scored into (0.2, 0.8)."""
        ll = np.asarray(drafter_logliks, np.float32)
        z = (ll - ll.mean()) / (ll.std() + 1e-6)
        self.scores[rid] = np.clip(0.5 + 0.15 * z, 0.2, 0.8).astype(np.float32)

    def update(self, rid: int, drafter_tokens: np.ndarray,
               drafter_conf: np.ndarray, accepted_tokens: Sequence[int],
               participated: Sequence[int]):
        """drafter_tokens/conf: (N, K) this iteration's proposals."""
        m = self.vector(rid).copy()
        ema = self.cfg.routing_ema
        for nd in participated:
            acc = verification_accuracy(self.embed, drafter_tokens[nd],
                                        accepted_tokens)
            s = routing_score(drafter_conf[nd], acc)
            m[nd] = ema * m[nd] + (1 - ema) * s
        self.scores[rid] = m
        return m

    def note_node_outcome(self, node: int, role: str,
                          ema: float = 0.8):
        """Cluster feedback after each cohort: how late was `node`?
        role: "fused" (on time) | "side" (late, salvaged) | "dropped"."""
        lateness = {"fused": 0.0, "side": 0.5, "dropped": 1.0}[role]
        self.node_lag[node] = ema * self.node_lag[node] \
            + (1.0 - ema) * lateness

    def _effective(self, m: np.ndarray) -> np.ndarray:
        """Routing scores discounted by chronic node lateness."""
        return m * (1.0 - self.cfg.straggler_penalty * self.node_lag)

    def route(self, rid: int, l_acc: float) -> List[int]:
        """Eq. (3): pick `drafters_per_request` drafters; each pick is
        top-scoring with prob coef, random otherwise. Both modes are
        down-weighted by chronic node lateness: the top order uses the
        lag-discounted scores, and the exploration draw is biased away
        from nodes that keep getting cut from cohorts."""
        m_eff = self._effective(self.vector(rid))
        coef = self.cfg.alpha if l_acc < self.cfg.tau else self.cfg.beta
        chosen: List[int] = []
        avail = list(range(self.n))
        order = sorted(avail, key=lambda i: -m_eff[i])
        for _ in range(min(self.cfg.drafters_per_request, self.n)):
            if self.rng.random() < coef:
                pick = next(i for i in order if i not in chosen)
            else:
                rest = [i for i in avail if i not in chosen]
                w = np.clip(1.0 - self.cfg.straggler_penalty
                            * self.node_lag[rest], 1e-3, None)
                pick = int(self.rng.choice(rest, p=w / w.sum()))
            chosen.append(pick)
        return sorted(chosen)

    def drop(self, rid: int):
        self.scores.pop(rid, None)
