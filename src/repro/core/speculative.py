"""Speculative verification: distribution-preserving rejection sampling
(Leviathan et al., the paper's §2.1 acceptance mechanism) plus the greedy
variant used for the paper's experiments (§6.1: greedy sampling for both
draft generation and verification).

Alignment convention: `target_logits[:, i]` is the target distribution for
draft token i, i.e. conditioned on everything *before* it (the engine
assembles this from the previous step's tail logits + the verify pass);
`bonus_logits` is the distribution after the last draft token.

All functions are vectorized over the batch and jit-friendly (fixed
shapes; acceptance counts are data, not shapes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def accept_counts_greedy(draft_tokens, target_argmax):
    """Greedy acceptance: token i is accepted iff every token <= i matches
    the target argmax. draft_tokens, target_argmax: (B, G) -> (B,) counts."""
    match = (draft_tokens == target_argmax)
    return jnp.cumprod(match.astype(jnp.int32), axis=-1).sum(axis=-1)


def verify_greedy(draft_tokens, target_logits, bonus_logits):
    """Greedy speculative verification.

    draft_tokens: (B, G); target_logits: (B, G, V); bonus_logits: (B, V).
    Returns:
      out_tokens (B, G+1): accepted prefix + 1 correction/bonus token
      n_out (B,): number of valid tokens (n_accepted + 1)
    Matches incremental greedy decoding exactly (losslessness invariant).
    """
    B, G = draft_tokens.shape
    full = jnp.concatenate([target_logits, bonus_logits[:, None]], axis=1)
    tgt = jnp.argmax(full, axis=-1)                             # (B, G+1)
    n_acc = accept_counts_greedy(draft_tokens, tgt[:, :G])      # (B,)
    fix = jnp.take_along_axis(tgt, n_acc[:, None], axis=1)[:, 0]
    out = jnp.concatenate([draft_tokens, jnp.zeros((B, 1), draft_tokens.dtype)],
                          axis=1)
    out = out.at[jnp.arange(B), n_acc].set(fix)
    return out, n_acc + 1


def verify_rejection(key, draft_tokens, draft_logprobs, target_logits,
                     bonus_logits, temperature: float = 1.0):
    """Stochastic rejection-sampling verification (lossless in
    distribution).

    draft_tokens:   (B, G) tokens sampled from the drafter(s)
    draft_logprobs: (B, G, V) drafter log-distributions at each position
    target_logits:  (B, G, V); bonus_logits: (B, V)
    Accept token i with prob min(1, p(x)/q(x)); at the first rejection
    resample from norm(max(0, p - q)); if all accepted, sample the bonus
    token from the target's post-draft distribution.

    Returns (out_tokens (B, G+1), n_out (B,)).
    """
    B, G, V = target_logits.shape
    p = jax.nn.softmax(target_logits.astype(jnp.float32) / temperature, -1)
    q = jnp.exp(draft_logprobs.astype(jnp.float32))

    p_tok = jnp.take_along_axis(p, draft_tokens[..., None], -1)[..., 0]  # (B,G)
    q_tok = jnp.take_along_axis(q, draft_tokens[..., None], -1)[..., 0]
    k_acc, k_res = jax.random.split(key)
    u = jax.random.uniform(k_acc, (B, G))
    accept = u < jnp.minimum(1.0, p_tok / jnp.maximum(q_tok, 1e-20))
    n_acc = jnp.cumprod(accept.astype(jnp.int32), -1).sum(-1)            # (B,)

    # residual distribution at the first rejected position
    idx = jnp.minimum(n_acc, G - 1)
    take = lambda a: jnp.take_along_axis(
        a, idx[:, None, None].repeat(V, -1), 1)[:, 0]
    resid = jnp.maximum(take(p) - take(q), 0.0)
    # all-accepted rows instead sample the bonus token from the target
    p_bonus = jax.nn.softmax(bonus_logits.astype(jnp.float32) / temperature, -1)
    resid = jnp.where((n_acc == G)[:, None], p_bonus, resid)
    resid = resid / jnp.maximum(resid.sum(-1, keepdims=True), 1e-20)
    fix = jax.random.categorical(k_res, jnp.log(jnp.maximum(resid, 1e-30)))

    out = jnp.concatenate([draft_tokens, jnp.zeros((B, 1), draft_tokens.dtype)],
                          axis=1)
    out = out.at[jnp.arange(B), n_acc].set(fix)
    return out, n_acc + 1


def sample_from_logits(key, logits, temperature: float = 0.0):
    """Greedy (temperature 0) or categorical sampling. logits: (..., V)."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)
    return jax.random.categorical(key, logits / temperature, axis=-1)
