"""SLO-aware admission control (DESIGN.md §2.5).

Under sustained overload the scheduler's batch assignment alone only
decides *who goes first* — nothing bounds how long the rest wait, and a
saturated verifier silently degrades every request's latency. The
admission layer sits between the request pool and the scheduler and
turns `PipelineObservation` saturation into explicit policy:

  * **queue** — cold (zero-token) requests beyond the admission cap are
    withheld from the scheduler's candidate set this cohort; they stay
    in the pool and age (the scheduler's aging credit guarantees they
    are eventually batched once admitted).
  * **shed** — a cold request that can no longer meet its deadline even
    if served alone (now + minimal service time > deadline) is rejected
    outright while the verifier saturates; serving it would be pure
    goodput loss. Overflow past the queue cap is shed worst-first
    (lowest priority class, latest deadline). Only zero-token requests
    are ever shed — a stream that has started always runs to completion
    (never half-committed).
  * **preempt** — when the batch is full of lower-priority in-flight
    requests and a more urgent class is waiting, the lowest-priority
    victim's slots are evicted (the cheap slot evict/re-admit path: its
    committed tokens survive in the pool; re-admission re-prefills
    prompt+generated and pays that prefill on the verify stage).
    Preemption is churn-damped: a request is evicted at most once in
    its lifetime, never once it is >= 75% complete, and at most one
    slot is evicted per admission pass.

Invariants: started requests are never shed; requests in the in-flight
verification cohort are never preempted (their caches are about to be
extended by the commit); when the pipe is empty the controller always
admits at least one candidate, so admission can never deadlock the
serve loop.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence

from repro.config import CoSineConfig
from repro.core.latency_model import LatencyModel
from repro.core.request_pool import Request
from repro.core.scheduler import PipelineObservation
from repro.obs.metrics import DecisionLog


@dataclass
class AdmissionDecision:
    """Outcome of one admission pass over the cohort candidates."""
    admit: List[Request] = field(default_factory=list)
    queued: List[Request] = field(default_factory=list)
    shed: List[Request] = field(default_factory=list)
    preempt: List[Request] = field(default_factory=list)   # active victims


class ServiceTimeEstimator:
    """Measured per-token service time under the *current* load
    (DESIGN.md §2.5): an EMA over observed iteration wall time divided
    by the tokens it committed, scaled to one request's share of the
    batch. The shed test consumes this instead of the analytic
    single-request optimum `t_llm(1, l, min_gamma)`, which is wildly
    optimistic exactly when admission matters — under saturation a cold
    request shares the verifier with a full batch. Estimate changes
    beyond 10% are recorded through the DecisionLog so the shed
    decisions' evidence trail is auditable."""

    def __init__(self, alpha: float = 0.3,
                 decisions: Optional[DecisionLog] = None):
        self.alpha = alpha
        self.decisions = decisions
        self.ms_per_tok: Optional[float] = None
        self._logged: float = 0.0
        self.n_obs = 0

    def observe(self, iter_ms: float, committed: int, batch: int,
                now_ms: float = 0.0) -> None:
        """One serving iteration: `batch` requests shared `iter_ms` of
        engine time and committed `committed` tokens, so one request's
        marginal cost is iter_ms * batch / committed per token."""
        if committed <= 0 or iter_ms <= 0:
            return
        obs = iter_ms * max(batch, 1) / committed
        if self.ms_per_tok is None:
            self.ms_per_tok = obs
        else:
            self.ms_per_tok += self.alpha * (obs - self.ms_per_tok)
        self.n_obs += 1
        if self.decisions is not None and (
                self._logged <= 0.0
                or abs(self.ms_per_tok - self._logged) > 0.1 * self._logged):
            self.decisions.record(now_ms, "service_est",
                                  ms_per_tok=self.ms_per_tok,
                                  n_obs=self.n_obs)
            self._logged = self.ms_per_tok


class AdmissionController:
    def __init__(self, cfg: CoSineConfig, lat: LatencyModel,
                 decisions: Optional[DecisionLog] = None):
        self.cfg = cfg
        self.lat = lat
        # controller decision log (DESIGN.md §2.6): each pass's verdict
        # is recorded with the saturation inputs it keyed on
        self.decisions = decisions
        # measured service-time evidence, fed by engine._finalize
        self.svc = ServiceTimeEstimator(decisions=decisions)

    # ----------------------------------------------------------- helpers
    def min_service_ms(self, r: Request) -> float:
        """Time-to-first-token estimate for the shed test. With measured
        evidence: prefill plus one committed token at the observed
        ms/token under current load. Before any iteration has been
        observed (cold start), the optimistic analytic bound — prefill
        plus one minimal solo verification — so a fresh controller
        never sheds on a guess."""
        pf = self.lat.t_prefill(r.context_len)
        if self.svc.ms_per_tok is not None:
            return pf + self.svc.ms_per_tok
        return (pf + self.lat.comm_ms
                + self.lat.t_llm(1, r.context_len, self.cfg.min_gamma))

    @staticmethod
    def _urgency(r: Request):
        """Shed/queue order: keep high priority classes and early
        deadlines, break ties by arrival."""
        return (r.priority, r.deadline_ms, r.arrival_ms, r.rid)

    # ------------------------------------------------------------ decide
    def decide(self, cands: Sequence[Request], now_ms: float,
               observation: Optional[PipelineObservation] = None,
               active: Sequence[Request] = (),
               n_protected: int = 0,
               pipe_empty: bool = False) -> AdmissionDecision:
        """Partition the cohort candidates.

        cands: schedulable requests (pool.pending filtered by arrival).
        active: requests currently holding slots that are legal
          preemption victims (prefilled, NOT in the in-flight
          verification cohort).
        n_protected: slot-holders that are *not* legal victims (the
          in-flight cohort) — they still occupy batch capacity.
        pipe_empty: nothing drafted or verifying — the controller must
          admit work if any exists.
        """
        cfg = self.cfg
        dec = AdmissionDecision()
        saturated = observation is not None and observation.saturated \
            and not pipe_empty

        started = [r for r in cands if r.generated]
        cold = sorted((r for r in cands if not r.generated),
                      key=self._urgency)
        dec.admit.extend(started)

        # --- shed: hopeless deadlines (only under saturation — with a
        # free verifier a late request still produces tokens at no cost
        # to anyone else, so it is served best-effort) ---
        if cfg.shed_when_late and saturated:
            keep = []
            for r in cold:
                if now_ms + self.min_service_ms(r) > r.deadline_ms:
                    dec.shed.append(r)
                else:
                    keep.append(r)
            cold = keep

        # --- queue cap: bound the cold backlog under saturation; the
        # overflow past 2x the cap is shed (worst-first order is already
        # applied), between cap and 2x it merely queues ---
        if cfg.admit_queue_cap > 0 and saturated \
                and len(cold) > cfg.admit_queue_cap:
            over = cold[cfg.admit_queue_cap:]
            cold = cold[: cfg.admit_queue_cap]
            dec.queued.extend(over[: cfg.admit_queue_cap])
            dec.shed.extend(over[cfg.admit_queue_cap:])

        dec.admit.extend(cold)
        # liveness floor: with an empty pipe, admission must hand the
        # scheduler at least one request if any candidate survived
        if not dec.admit and dec.queued:
            dec.admit.append(dec.queued.pop(0))

        # --- priority preemption: urgent cold arrivals displace the
        # lowest-priority active slots when the batch is full. Only
        # under saturation: with verifier headroom the scheduler batches
        # the arrival next cohort anyway, so eviction would just burn a
        # re-prefill. Damped against churn — every eviction costs a
        # re-prefill, so a request is only ever evicted once, never when
        # it is mostly done (>= 75% of its tokens committed), and at
        # most one slot is evicted per admission pass ---
        if cfg.preempt_priority and saturated and active:
            eligible = [v for v in sorted(active, key=self._urgency,
                                          reverse=True)
                        if v.n_preemptions == 0
                        and 4 * len(v.generated) < 3 * v.max_new_tokens]
            waiting = sorted((r for r in dec.admit if not r.generated),
                             key=self._urgency)
            slots_free = cfg.max_batch - n_protected - len(active)
            for hi in waiting:
                if slots_free > 0:
                    slots_free -= 1     # room without preempting
                    continue
                if not eligible:
                    break
                if hi.priority < eligible[0].priority:
                    dec.preempt.append(eligible.pop(0))
                break                   # one eviction per pass

        if self.decisions is not None and (cands or active):
            self.decisions.record(
                now_ms, "admission",
                n_cands=len(cands), saturated=saturated,
                pipe_empty=pipe_empty,
                queue_depth=(observation.queue_depth
                             if observation is not None else 0),
                verify_busy_frac=(observation.verify_busy_frac
                                  if observation is not None else 0.0),
                svc_ms_per_tok=(self.svc.ms_per_tok
                                if self.svc.ms_per_tok is not None else -1.0),
                admitted=tuple(r.rid for r in dec.admit),
                queued=tuple(r.rid for r in dec.queued),
                shed=tuple(r.rid for r in dec.shed),
                preempted=tuple(r.rid for r in dec.preempt))
        return dec
