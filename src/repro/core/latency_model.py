"""Hardware latency/cost model (paper §4.3 "experimentally modeled"
T_ssm / T_llm, and Table 1 hardware constants).

This container is CPU-only, so the *scheduling* layer reasons about the
paper's deployment (consumer-GPU speculation cluster + datacenter-GPU
verification server) through this calibrated analytic model, while the
*token-level* computation is executed for real by the JAX models. The
model is linear in the quantities the paper identifies (batch size b,
critical length l, draft tokens gamma / verified tokens Gamma) and can be
refitted from measured samples via `fit()` (least squares).

Role split since the discrete-event executor (DESIGN.md §2/§3): this
model supplies *per-stage primitives only* — `t_ssm` (one drafting pass
on the cluster), `t_llm` (one verification forward on the server) and
`comm_ms` (cluster->server transfer). How those stages overlap is no
longer a formula: the executor (serving/pipeline.py) places them on
per-stage event clocks and measures the result. The closed-form
`iteration_coupled` remains the accounting for the coupled baselines
(ar/vanilla/specinfer), and `iteration_pipelined` survives only as the
scheduler's analytic planning estimate of a steady-state period — the
serving path never charges it.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

# ---- Table 1 (paper) ----
HW = {
    "2080Ti": dict(flops=107.6e12, bw=616e9, ssm_tps=350.0, llm_tps=None,
                   rent=0.12, deploy=200),
    "3090": dict(flops=285e12, bw=936e9, ssm_tps=450.0, llm_tps=None,
                 rent=0.22, deploy=1000),
    "A100": dict(flops=5144e12 / 16, bw=2039e9, ssm_tps=9500.0, llm_tps=7.13,
                 rent=5.67, deploy=60000),
}


@dataclass(frozen=True)
class DrafterProfile:
    """Per-drafter-node latency personality (heterogeneous cluster).

    The paper's speculation side is a *cluster* of consumer-GPU nodes, so
    each drafter carries its own multiplier on the drafting step time, its
    own link delay to the verification server, and a deterministic, seeded
    jitter/straggler model (DESIGN.md §2.4):

      speed           — step-time multiplier (2.0 = a 2x slower node)
      comm_ms         — node->server transfer; None inherits the global
      jitter_frac     — lognormal sigma of per-job pace noise
      straggle_prob   — per-job probability of a straggle episode
      straggle_factor — pace multiplier during a straggle episode
    """
    speed: float = 1.0
    comm_ms: float | None = None
    jitter_frac: float = 0.0
    straggle_prob: float = 0.0
    straggle_factor: float = 4.0


def homogeneous_profiles(n: int) -> tuple:
    """Default cluster: n identical, jitter-free nodes (the seed's
    single-clock behaviour decomposed per node)."""
    return tuple(DrafterProfile() for _ in range(n))


# Default pace multiple of a weight-only-int8 drafter node (DESIGN.md
# §2.9): the drafter decode step is memory-roofline-bound on the weight
# stream (§3.2), and int8 halves it; activations, KV traffic and the
# host dispatch floor keep the realized step from a clean 0.5x — 0.6 is
# the analytic-roofline estimate (analysis/analytic.py weight-bytes
# term) and `calibrated_profiles()` recovers whatever pace the node
# actually sustains from its measured (b, l, step_ms) observations.
INT8_DRAFT_SPEED = 0.6


def pool_profiles(drafter_cfgs) -> tuple:
    """Per-node default profiles for a possibly mixed-precision pool:
    int8 weight-only nodes draft at `INT8_DRAFT_SPEED` x the bf16 step,
    everything else keeps the homogeneous default."""
    return tuple(
        DrafterProfile(speed=INT8_DRAFT_SPEED
                       if getattr(c, "quant", "") == "int8" else 1.0)
        for c in drafter_cfgs)


@dataclass
class LatencyModel:
    """T_ssm(b, l, gamma) and T_llm(b, l, Gamma) in milliseconds.

    T_ssm: sequential drafting — gamma autoregressive steps, each step
      memory-bound (weight streaming) with a mild context and batch term.
    T_llm: one parallel verification forward — base cost plus terms in the
      total verified tokens Gamma and KV/attention traffic b*l.
    """
    # drafter node (consumer GPU, e.g. 2080Ti): per-token step cost
    ssm_step_ms: float = 1000.0 / HW["2080Ti"]["ssm_tps"]   # ~2.86 ms/token
    ssm_ctx_ms_per_ktok: float = 0.08      # context-length term per step
    ssm_batch_ms: float = 0.12             # per extra request in the batch
    # verification server (4xA100, Table 1: 7.13 tok/s AR for the whole
    # server -> ~140 ms per forward); parallel verification of Gamma draft
    # tokens reuses the same weight pass (the paper's core premise), so the
    # per-token term is small
    llm_base_ms: float = 1000.0 / HW["A100"]["llm_tps"]      # ~140 ms/fwd
    llm_token_ms: float = 0.3              # per verified tree token
    llm_ctx_ms_per_ktok: float = 0.25      # per request-kilotoken of KV read
    # communication (10 Gbps, sub-1ms; token-level payloads)
    comm_ms: float = 0.8

    def t_ssm(self, b: int, l: int, gamma: int, n_drafters: int = 1) -> float:
        step = (self.ssm_step_ms + self.ssm_ctx_ms_per_ktok * l / 1000.0
                + self.ssm_batch_ms * max(b - 1, 0))
        # parallel drafters work concurrently; fusion syncs per step
        sync = 0.05 * max(n_drafters - 1, 0)
        return gamma * (step + sync)

    # ---- per-drafter-node primitives (heterogeneous cluster, §2.4) ----
    def ssm_step_node(self, b: int, l: int, profile: DrafterProfile,
                      pace_mult: float = 1.0) -> float:
        """One drafting step on one cluster node: the homogeneous step
        cost scaled by the node's speed and its (seeded) per-job pace
        multiplier. The fusion sync term is a *cluster* property (it
        depends on who the node syncs with), so it lives in
        serving/cluster.py, not here."""
        step = (self.ssm_step_ms + self.ssm_ctx_ms_per_ktok * l / 1000.0
                + self.ssm_batch_ms * max(b - 1, 0))
        return step * profile.speed * pace_mult

    def sync_ms(self, n_sync: int) -> float:
        """Per-step fusion synchronisation overhead for n_sync lock-step
        nodes (matches the homogeneous t_ssm's sync term)."""
        return 0.05 * max(n_sync - 1, 0)

    def node_comm_ms(self, profile: DrafterProfile) -> float:
        return self.comm_ms if profile.comm_ms is None else profile.comm_ms

    def t_llm(self, b: int, l: int, big_gamma: int) -> float:
        return (self.llm_base_ms + self.llm_token_ms * big_gamma
                + self.llm_ctx_ms_per_ktok * b * l / 1000.0)

    def t_prefill(self, l: int) -> float:
        """One prompt forward of l tokens on the verification server —
        same weight pass as verification, l tokens scored in parallel.
        The pipelined executor charges it as a verify-stage job so TTFT
        includes the cold-start prefill (DESIGN.md §2.2)."""
        return self.t_llm(1, l, l)

    def iteration_coupled(self, b, l, gamma, big_gamma, n_drafters=1,
                          prefill_ms: float = 0.0,
                          draft_b: int | None = None) -> float:
        """Sequential draft -> verify (vanilla/SpecInfer). `prefill_ms`
        is the serialized prompt-forward time for the iteration's cold
        requests — the coupled baselines pay TTFT on the same server the
        pipelined strategies do (no free prefills). `draft_b` is the
        drafting-side batch when it differs from the verified one (routed
        sub-batches: the most loaded node's share, not the cohort)."""
        return (prefill_ms
                + self.t_ssm(b if draft_b is None else draft_b, l, gamma,
                             n_drafters)
                + self.comm_ms + self.t_llm(b, l, big_gamma))

    def iteration_pipelined(self, b, l, gamma, big_gamma, n_drafters=1) -> float:
        """Analytic steady-state period of a perfectly overlapped pipeline:
        max(stages), the non-dominant stage hidden behind the dominant one.
        Planning estimate only (scheduler Eq. 8 / baseline comparisons) —
        execution-time overlap is measured by the event-driven executor,
        which also pays invalidation redrafts this formula ignores."""
        return max(self.t_ssm(b, l, gamma, n_drafters) + self.comm_ms,
                   self.t_llm(b, l, big_gamma))

    # ---- cost accounting (Table 3) ----
    def cost_per_ms(self, n_drafter_nodes: int, drafter_gpu="2080Ti",
                    n_server_gpus: int = 4) -> float:
        """$ per millisecond of wall time for the deployment."""
        hourly = (n_drafter_nodes * HW[drafter_gpu]["rent"]
                  + n_server_gpus * HW["A100"]["rent"])
        return hourly / 3600.0 / 1000.0

    # ---- calibration ----
    def fit_ssm(self, samples):
        """samples: list of (b, l, gamma, measured_ms). Least-squares refit."""
        A = np.array([[g, g * l / 1000.0, g * max(b - 1, 0)]
                      for b, l, g, _ in samples])
        y = np.array([t for *_, t in samples])
        coef, *_ = np.linalg.lstsq(A, y, rcond=None)
        self.ssm_step_ms, self.ssm_ctx_ms_per_ktok, self.ssm_batch_ms = map(
            float, np.maximum(coef, 1e-6))

    def fit_llm(self, samples):
        """samples: list of (b, l, Gamma, measured_ms)."""
        A = np.array([[1.0, g, b * l / 1000.0] for b, l, g, _ in samples])
        y = np.array([t for *_, t in samples])
        coef, *_ = np.linalg.lstsq(A, y, rcond=None)
        self.llm_base_ms, self.llm_token_ms, self.llm_ctx_ms_per_ktok = map(
            float, np.maximum(coef, 1e-6))
