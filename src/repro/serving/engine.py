"""The CoSine serving engine (paper §4) and its baselines.

Strategies (DESIGN.md §1):
  ar         — vLLM-style incremental decoding (no speculation)
  vanilla    — single-drafter chain speculation, coupled execution
  specinfer  — all drafters draft independent chains, merged into a token
               tree, coupled (synchronous) execution
  pipeinfer  — single-drafter chain, decoupled pipelined execution
  cosine     — the paper: adaptive routing (Eq. 1-3) + confidence-based
               token fusion (Eq. 4) + tree verification + collaborative
               pipeline (Eq. 5-8, Alg. 2)

Token-level computation (drafting, verification, acceptance) is executed
for real by the JAX models; wall-clock of the paper's heterogeneous
GPU deployment is accounted by the calibrated LatencyModel (DESIGN.md §3),
so latency/throughput/cost metrics are reported in *simulated* deployment
time while correctness (losslessness) is real.

Cache ownership: each ModelRunner owns one slot-based device-resident
cache (continuous batching); the engine addresses requests by rid and the
runner's SlotCacheManager maps rids to slots. Prefill admits a slot,
completion evicts it, and speculative drafting runs on discarded slot
snapshots — there is no per-request cache dict or per-step host
stack/split anywhere in the serving path.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import CoSineConfig, ModelConfig
from repro.core import tree as tree_mod
from repro.core.latency_model import LatencyModel
from repro.core.request_pool import Request, RequestPool
from repro.core.routing import AdaptiveRouter
from repro.core.scheduler import RequestScheduler, adaptive_speculation
from repro.core.speculative import verify_greedy
from repro.serving.runner import ModelRunner

STRATEGIES = ("ar", "vanilla", "specinfer", "pipeinfer", "cosine")


@dataclass
class IterationRecord:
    t_start_ms: float
    t_iter_ms: float
    batch: int
    big_gamma: int
    committed: int
    n_active_drafters: int


@dataclass
class ServeStats:
    records: List[IterationRecord] = field(default_factory=list)
    total_committed: int = 0
    total_drafted: int = 0

    @property
    def sim_ms(self) -> float:
        return (self.records[-1].t_start_ms + self.records[-1].t_iter_ms
                if self.records else 0.0)

    @property
    def throughput_tps(self) -> float:
        return self.total_committed / max(self.sim_ms / 1000.0, 1e-9)

    @property
    def mean_acceptance(self) -> float:
        return self.total_committed / max(len(self.records), 1)


class SpeculativeEngine:
    def __init__(self, target: Tuple[ModelConfig, dict],
                 drafters: Sequence[Tuple[ModelConfig, dict, str]],
                 cosine: CoSineConfig, strategy: str = "cosine",
                 latency: Optional[LatencyModel] = None,
                 max_len: int = 512, seed: int = 0,
                 eos_token: Optional[int] = None):
        assert strategy in STRATEGIES, strategy
        self.strategy = strategy
        self.cfg = cosine
        self.eos = eos_token
        self.target_cfg, target_params = target
        self.target = ModelRunner(self.target_cfg, target_params, max_len)
        self.drafters = [ModelRunner(c, p, max_len) for c, p, _ in drafters]
        self.drafter_domains = [d for _, _, d in drafters]
        self.lat = latency or LatencyModel()
        self.pool = RequestPool()
        self.router = AdaptiveRouter(len(self.drafters), cosine,
                                     self.target.embed_np, seed)
        self.sched = RequestScheduler(cosine, self.lat)
        self.stats = ServeStats()
        self.clock_ms = 0.0
        self.entry_logits: Dict[int, np.ndarray] = {}
        self.rng = np.random.default_rng(seed)
        # SSM/hybrid verifiers cannot apply tree masks -> chain-only trees
        self.tree_capable = self.target_cfg.family not in ("ssm", "hybrid")

    # ------------------------------------------------------------ requests
    def submit(self, prompt, max_new_tokens: int = 32, domain=None,
               arrival_ms: float = 0.0) -> Request:
        r = self.pool.add(prompt, max_new_tokens, domain, arrival_ms)
        r.gamma = self.cfg.draft_len
        return r

    def _ensure_prefilled(self, r: Request):
        if r.rid in self.entry_logits:
            return
        ctx = list(r.prompt) + r.generated
        self.entry_logits[r.rid], _ = self.target.prefill_request(r.rid, ctx)
        if self.strategy != "ar":
            lls = []
            for d in self.drafters:
                _, ll = d.prefill_request(r.rid, ctx)
                lls.append(ll)
            if self.strategy == "cosine" and self.cfg.enable_routing:
                # content-based routing prior (paper §5 request analysis)
                self.router.set_prior(r.rid, lls)

    # ------------------------------------------------------------ drafting
    def _participants(self, r: Request) -> List[int]:
        n = len(self.drafters)
        if self.strategy == "cosine":
            if not self.cfg.enable_routing:   # ablation: random assignment
                k = min(self.cfg.drafters_per_request, n)
                return sorted(self.rng.choice(n, size=k, replace=False).tolist())
            return self.router.route(r.rid, r.l_acc_ema)
        if self.strategy == "specinfer":
            return list(range(n))
        return [0]

    def _draft(self, batch: List[Request], gammas: List[int]):
        """Run the speculation cluster for one iteration.

        Returns per-request dicts: draft tree, plus (tokens, confs) per
        drafter for routing updates."""
        B = len(batch)
        K = max(gammas)
        rids = [r.rid for r in batch]
        parts = [self._participants(r) for r in batch]
        fuse = self.strategy == "cosine" and self.cfg.enable_fusion

        # slot-snapshot drafting: one device-side gather per drafter; the
        # snapshots are decoded on and then discarded (= rollback) — the
        # slot-resident caches only advance at commit time.
        temp = [d.speculative_caches(rids) for d in self.drafters]

        prev = np.array([ (r.generated[-1] if r.generated else r.prompt[-1])
                          for r in batch], np.int32)
        prev_per_d = [prev.copy() for _ in self.drafters]

        all_tokens = np.zeros((len(self.drafters), B, K), np.int32)
        all_confs = np.zeros((len(self.drafters), B, K), np.float32)
        chain_tokens = np.zeros((B, K), np.int32)
        chain_probs = np.zeros((B, K), np.float32)

        for i in range(K):
            step_tokens = np.zeros((len(self.drafters), B), np.int32)
            step_confs = np.full((len(self.drafters), B), -1.0, np.float32)
            for di, d in enumerate(self.drafters):
                lg, temp[di] = d.decode(rids, prev_per_d[di], caches=temp[di])
                probs = jax.nn.softmax(jnp.asarray(lg), -1)
                tok = np.asarray(jnp.argmax(probs, -1))
                conf = np.asarray(jnp.take_along_axis(
                    probs, jnp.asarray(tok)[:, None], -1))[:, 0]
                step_tokens[di] = tok
                step_confs[di] = conf
            all_tokens[:, :, i] = step_tokens
            all_confs[:, :, i] = np.maximum(step_confs, 0.0)

            # confidence-based token fusion (Eq. 4)
            fused = np.zeros(B, np.int32)
            fused_p = np.zeros(B, np.float32)
            for b in range(B):
                cand = parts[b]
                masked = np.full(len(self.drafters), -1.0)
                masked[cand] = step_confs[cand, b]
                best = int(np.argmax(masked))
                fused[b] = step_tokens[best, b]
                fused_p[b] = max(masked[best], 0.0)
            chain_tokens[:, i] = fused
            chain_probs[:, i] = fused_p

            if fuse:
                for di in range(len(self.drafters)):
                    prev_per_d[di] = fused.copy()
            elif self.strategy in ("specinfer", "cosine"):
                # independent chains (SpecInfer; CoSine w/o fusion ablation)
                for di in range(len(self.drafters)):
                    prev_per_d[di] = step_tokens[di].copy()
            else:  # single-drafter chain
                for di in range(len(self.drafters)):
                    prev_per_d[di] = step_tokens[0].copy()

        # ---- build trees ----
        trees = []
        for b, r in enumerate(batch):
            g = gammas[b]
            if self.strategy == "cosine" and self.tree_capable \
                    and self.cfg.tree_width > 0:
                side_t = all_tokens[:, b, :g].T            # (g, N)
                side_p = np.where(
                    np.isin(np.arange(len(self.drafters)), parts[b]),
                    all_confs[:, b, :g].T, -1.0)
                side_d = np.broadcast_to(np.arange(len(self.drafters)),
                                         (g, len(self.drafters)))
                t = tree_mod.build_tree(chain_tokens[b, :g], chain_probs[b, :g],
                                        side_t, side_p, side_d,
                                        self.cfg.tree_width)
            elif self.strategy == "specinfer" and self.tree_capable:
                t = tree_mod.build_tree(
                    chain_tokens[b, :g], chain_probs[b, :g],
                    all_tokens[:, b, :g].T, all_confs[:, b, :g].T,
                    np.broadcast_to(np.arange(len(self.drafters)),
                                    (g, len(self.drafters))),
                    tree_width=max(len(self.drafters) - 1, 1))
            else:
                t = tree_mod.chain_tree(chain_tokens[b, :g], chain_probs[b, :g])
            trees.append(t)
        return trees, all_tokens, all_confs, parts

    # ------------------------------------------------------------ one step
    def step(self) -> Optional[IterationRecord]:
        pending = self.pool.pending(self.clock_ms)
        if not pending:
            future = [r.arrival_ms for r in self.pool.pending(float("inf"))]
            if not future:
                return None
            self.clock_ms = min(future)   # idle until next arrival
            pending = self.pool.pending(self.clock_ms)

        for r in pending:
            self._ensure_prefilled(r)

        if self.strategy == "ar":
            return self._step_ar(pending)

        pipelined = self.strategy in ("pipeinfer", "cosine")
        use_sched = self.strategy == "cosine"
        if use_sched:
            plan = self.sched.plan(pending, pipelined=pipelined,
                                   n_drafters=self.cfg.drafters_per_request)
            batch, gammas = plan.requests, plan.gammas
        else:
            batch = sorted(pending, key=lambda r: r.arrival_ms)[: self.cfg.max_batch]
            gammas = [self.cfg.draft_len] * len(batch)

        trees, all_tokens, all_confs, parts = self._draft(batch, gammas)

        # ---- batched tree verification ----
        M_nodes = max(t.n_nodes for t in trees)
        padded = tree_mod.pad_trees(trees, M_nodes)
        rids = [r.rid for r in batch]
        node_logits = self.target.verify(rids, padded["tokens"],
                                         padded["rel_pos"], padded["mask"])

        committed: Dict[int, List[int]] = {}
        total_committed = 0
        for b, r in enumerate(batch):
            t = trees[b]
            node_argmax = np.argmax(node_logits[b, : t.n_nodes], -1)
            entry_argmax = int(np.argmax(self.entry_logits[r.rid]))
            acc_tokens, acc_nodes, correction = tree_mod.accept_tree_greedy(
                t, node_argmax, entry_argmax)
            toks = acc_tokens + [int(correction)]
            remaining = r.max_new_tokens - len(r.generated)
            toks = toks[: max(remaining, 1)]
            if self.eos is not None and self.eos in toks:
                toks = toks[: toks.index(self.eos) + 1]
            committed[r.rid] = toks
            total_committed += len(toks)
            r.record_acceptance(len(toks), gammas[b])
            # routing update (Eq. 1-2) from this iteration's evidence
            if self.strategy == "cosine":
                self.router.update(r.rid, all_tokens[:, b, :], all_confs[:, b, :],
                                   toks, parts[b])

        # ---- commit to target + drafters ----
        tails = self.target.extend_committed(committed)
        for rid, lg in tails.items():
            self.entry_logits[rid] = lg
        for d in self.drafters:
            d.extend_committed(committed)

        # ---- bookkeeping / simulated time ----
        b = len(batch)
        l = max(r.context_len for r in batch)
        gmax = max(gammas)
        big_gamma = sum(t.n_nodes for t in trees)
        n_active = (sum(len(p) for p in parts) / b if self.strategy == "cosine"
                    else (len(self.drafters) if self.strategy == "specinfer" else 1))
        if pipelined:
            t_iter = self.lat.iteration_pipelined(b, l, gmax, big_gamma,
                                                  max(int(np.ceil(n_active)), 1))
        else:
            t_iter = self.lat.iteration_coupled(b, l, gmax, big_gamma,
                                                max(int(np.ceil(n_active)), 1))
        rec = IterationRecord(self.clock_ms, t_iter, b, big_gamma,
                              total_committed, int(np.ceil(n_active)))
        self._finalize(batch, committed, rec)
        if self.strategy == "cosine":
            busy = self.lat.t_llm(b, l, big_gamma) / max(t_iter, 1e-9)
            for r, g in zip(batch, gammas):
                if not r.done:
                    self.sched.update_gamma_feedback(
                        r, len(committed[r.rid]), busy)
        return rec

    def _step_ar(self, pending: List[Request]) -> IterationRecord:
        batch = sorted(pending, key=lambda r: r.arrival_ms)[: self.cfg.max_batch]
        committed: Dict[int, List[int]] = {}
        for r in batch:
            tok = int(np.argmax(self.entry_logits[r.rid]))
            committed[r.rid] = [tok]
        tails = self.target.extend_committed(committed)
        for rid, lg in tails.items():
            self.entry_logits[rid] = lg
        b = len(batch)
        l = max(r.context_len for r in batch)
        t_iter = self.lat.t_llm(b, l, b)
        rec = IterationRecord(self.clock_ms, t_iter, b, b, b, 0)
        for r in batch:
            r.record_acceptance(1, 0)
        self._finalize(batch, committed, rec)
        return rec

    def _finalize(self, batch, committed, rec: IterationRecord):
        self.clock_ms += rec.t_iter_ms
        self.stats.records.append(rec)
        self.stats.total_committed += rec.committed
        self.stats.total_drafted += rec.big_gamma
        for r in batch:
            toks = committed[r.rid]
            if r.first_token_ms < 0 and toks:
                r.first_token_ms = self.clock_ms
            r.generated.extend(toks)
            hit_eos = self.eos is not None and self.eos in toks
            if len(r.generated) >= r.max_new_tokens or hit_eos:
                self.pool.finish(r.rid, self.clock_ms)
                self.target.drop(r.rid)
                for d in self.drafters:
                    d.drop(r.rid)
                self.entry_logits.pop(r.rid, None)
                self.router.drop(r.rid)

    def run(self, max_iterations: int = 10_000) -> ServeStats:
        for _ in range(max_iterations):
            if self.step() is None:
                break
        return self.stats
