"""The CoSine serving engine (paper §4) and its baselines.

Strategies (DESIGN.md §1):
  ar         — vLLM-style incremental decoding (no speculation)
  vanilla    — single-drafter chain speculation, coupled execution
  specinfer  — all drafters draft independent chains, merged into a token
               tree, coupled (synchronous) execution
  pipeinfer  — single-drafter chain, decoupled pipelined execution
  cosine     — the paper: adaptive routing (Eq. 1-3) + confidence-based
               token fusion (Eq. 4) + tree verification + collaborative
               pipeline (Eq. 5-8, Alg. 2)

Execution model (DESIGN.md §2): `ar`/`vanilla`/`specinfer` run the
coupled path — draft, then verify, strictly in sequence, with the
iteration charged by the analytic `LatencyModel.iteration_coupled`.
`pipeinfer`/`cosine` run on the discrete-event `PipelineExecutor`
(serving/pipeline.py): the speculation cluster and the verification
server advance separate simulated clocks, the cluster drafts iteration
i+1 (optimistically, on slot snapshots) while the server verifies
iteration i, and draft/verify overlap — including verifier bubbles,
queueing, and draft-ahead invalidation on rejection — is *measured from
the event timeline* rather than assumed by a formula.

Token-level computation (drafting, verification, acceptance) is executed
for real by the JAX models; wall-clock of the paper's heterogeneous
GPU deployment is accounted by the calibrated LatencyModel (DESIGN.md §3),
so latency/throughput/cost metrics are reported in *simulated* deployment
time while correctness (losslessness) is real.

Cache ownership: each ModelRunner owns one slot-based device-resident
cache (continuous batching); the engine addresses requests by rid and the
runner's SlotCacheManager maps rids to slots. Prefill admits a slot,
completion evicts it, and speculative drafting runs on discarded slot
snapshots — there is no per-request cache dict or per-step host
stack/split anywhere in the serving path. Drafter caches are kept one
token *behind* the committed stream (prefilled on ctx[:-1], committed
with [prev, toks[:-1]]) so the draft loop's first `decode(prev)` feeds
the last committed token exactly once — drafter chains condition on the
same context the target verifies (DESIGN.md §1.1).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import CoSineConfig, ModelConfig
from repro.core import tree as tree_mod
from repro.core.admission import AdmissionController
from repro.core.latency_model import (DrafterProfile, LatencyModel,
                                      pool_profiles)
from repro.core.request_pool import Request, RequestPool
from repro.models.quantize import resolve_drafter_quant
from repro.core.routing import AdaptiveRouter
from repro.core.scheduler import (PipelineObservation, RequestScheduler,
                                  adaptive_speculation)
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import STAGE, Tracer
from repro.serving.backend import (ExecutionBackend, VerifyHandle,
                                   make_backend)
from repro.serving.events import DRAFT, VERIFY

STRATEGIES = ("ar", "vanilla", "specinfer", "pipeinfer", "cosine")
PIPELINED_STRATEGIES = ("pipeinfer", "cosine")


@dataclass
class IterationRecord:
    """Accounting for one serving iteration (one cohort through
    draft -> verify -> commit)."""

    t_start_ms: float
    t_iter_ms: float
    batch: int
    big_gamma: int
    committed: int
    n_active_drafters: int
    # cohort sequence number (engine-global, monotone): joins this
    # record to its trace spans and decision-log entries (DESIGN.md §2.6)
    cohort: int = -1
    # --- stage-level timeline (DESIGN.md §2.2): measured on the event
    # clocks for pipelined strategies, analytic decomposition for the
    # coupled baselines (where the verifier provably idles during
    # drafting and communication).
    draft_start_ms: float = 0.0
    draft_ms: float = 0.0
    verify_start_ms: float = 0.0
    verify_ms: float = 0.0
    verify_idle_ms: float = 0.0          # bubble before this verification
    prefill_ms: float = 0.0              # prompt forwards charged to the
    #                                      verify stage this iteration
    queue_depth: int = 0                 # drafted cohorts waiting at commit
    n_invalidated: int = 0               # draft-ahead entries rejected
    # --- per-drafter cluster accounting (DESIGN.md §2.4): busy time each
    # node spent on this iteration's cohort (draft + any redrafts), and
    # how many chains were demoted to side branches / dropped outright by
    # the straggler policy. Empty/zero under the coupled baselines.
    node_busy_ms: Tuple[float, ...] = ()
    n_straggler_side: int = 0
    n_straggler_dropped: int = 0


@dataclass
class ServeStats:
    """Serving aggregates, backed by the metrics registry (DESIGN.md
    §2.6): the engine increments registry counters as it serves, and the
    legacy fields are read-only views over them — the registry is the
    single source, so a metrics JSON export and these properties can
    never disagree. Per-iteration detail stays in `records`."""
    records: List[IterationRecord] = field(default_factory=list)
    metrics: MetricsRegistry = field(default_factory=MetricsRegistry)

    def add_record(self, rec: IterationRecord):
        """Fold one iteration into the registry. Increment order mirrors
        the old per-record sums exactly (same float accumulation), so
        equality tests against the stage clocks keep holding."""
        self.records.append(rec)
        m = self.metrics
        m.inc("serve.iterations")
        m.inc("serve.committed_tokens", rec.committed)
        m.inc("serve.drafted_tokens", rec.big_gamma)
        m.inc("verify.busy_ms", rec.verify_ms + rec.prefill_ms)
        m.inc("verify.prefill_ms", rec.prefill_ms)
        m.inc("verify.idle_ms", rec.verify_idle_ms)
        m.observe("serve.iter_ms", rec.t_iter_ms)
        m.observe("serve.commit_per_iter", rec.committed)
        m.observe("serve.batch_size", rec.batch)

    def note_draft_work(self, node: int, n_nodes: int, n_tokens: int):
        """Charge `n_tokens` drafter token-decodes to `node`."""
        g = self.metrics.gauge("draft.n_nodes")
        if g.value < n_nodes:
            g.set(n_nodes)
        self.metrics.inc("draft.node_tokens", n_tokens, node=node)
        self.metrics.inc("draft.calls", n_tokens)

    def note_shed(self):
        """Count one admission rejection."""
        self.metrics.inc("admission.shed")

    def note_preempt(self):
        """Count one priority preemption (slot eviction)."""
        self.metrics.inc("admission.preempted")

    @property
    def total_committed(self) -> int:
        """Tokens committed across all requests."""
        return int(self.metrics.value("serve.committed_tokens"))

    @property
    def total_drafted(self) -> int:
        """Draft tokens proposed across all cohorts."""
        return int(self.metrics.value("serve.drafted_tokens"))

    # --- admission-control outcomes (DESIGN.md §2.5) ---
    @property
    def n_shed(self) -> int:
        """Requests rejected by admission."""
        return int(self.metrics.value("admission.shed"))

    @property
    def n_preempted(self) -> int:
        """Slot evictions (priority preemption)."""
        return int(self.metrics.value("admission.preempted"))

    # --- route-faithful drafting compute (DESIGN.md §2.4) ---
    @property
    def draft_calls(self) -> int:
        """Total drafter token-decodes executed: the sum over cohorts and
        nodes of K * |sub-batch|. With routed sub-batches this is ~k*B*K
        per cohort; the legacy full fan-out paid N*B*K."""
        return int(self.metrics.value("draft.calls"))

    @property
    def node_drafted(self) -> List[int]:
        """node_drafted[i]: token-decodes node i executed (its routed
        sub-batch sizes times the draft length, over cohorts+redrafts)."""
        n = int(self.metrics.value("draft.n_nodes"))
        return [int(self.metrics.value("draft.node_tokens", node=i))
                for i in range(n)]

    @property
    def sim_ms(self) -> float:
        """Simulated end time of the last iteration (ms)."""
        return (self.records[-1].t_start_ms + self.records[-1].t_iter_ms
                if self.records else 0.0)

    @property
    def throughput_tps(self) -> float:
        """Committed tokens per simulated second."""
        return self.total_committed / max(self.sim_ms / 1000.0, 1e-9)

    @property
    def mean_acceptance(self) -> float:
        """Mean committed tokens per iteration."""
        return self.total_committed / max(len(self.records), 1)

    # --- pipeline health (DESIGN.md §2.2) ---
    @property
    def verifier_busy_ms(self) -> float:
        """Verification + prefill forwards: everything occupying the
        verification server (matches the executor's verify StageClock)."""
        return self.metrics.value("verify.busy_ms")

    @property
    def prefill_busy_ms(self) -> float:
        """Prefill share of the verification server's busy time."""
        return self.metrics.value("verify.prefill_ms")

    @property
    def verifier_idle_ms(self) -> float:
        """Total pipeline bubble time observed ahead of verifications."""
        return self.metrics.value("verify.idle_ms")

    @property
    def verifier_utilization(self) -> float:
        """busy / (busy + idle) of the verification server."""
        busy, idle = self.verifier_busy_ms, self.verifier_idle_ms
        return busy / max(busy + idle, 1e-9)

    @property
    def n_invalidated(self) -> int:
        """Draft-ahead cohorts invalidated by acceptance divergence."""
        return sum(r.n_invalidated for r in self.records)

    # --- drafter cluster health (DESIGN.md §2.4) ---
    @property
    def drafter_busy_ms(self) -> Tuple[float, ...]:
        """Per-node busy time summed over all iteration records."""
        width = max((len(r.node_busy_ms) for r in self.records), default=0)
        out = [0.0] * width
        for r in self.records:
            for i, v in enumerate(r.node_busy_ms):
                out[i] += v
        return tuple(out)

    @property
    def n_straggler_side(self) -> int:
        """Late drafter proposals demoted to side branches."""
        return sum(r.n_straggler_side for r in self.records)

    @property
    def n_straggler_dropped(self) -> int:
        """Late drafter proposals dropped outright."""
        return sum(r.n_straggler_dropped for r in self.records)


@dataclass
class DraftEntry:
    """One request's drafted speculation for one iteration.

    `d_toks`/`d_confs` (N, gamma) are every drafter's proposals (router
    evidence + tree side branches); `d_chains` (N, gamma) are the tokens
    each drafter actually *consumed* while chaining (equal to the fused
    chain when fusion is on) — the teacher-forcing script that recreates
    the drafter state for optimistic draft-ahead. `assumed`, when set,
    is the context extension beyond the committed stream this draft was
    conditioned on (draft-ahead); it is resolved against the actually
    committed tokens when the depended-on verification lands.
    """
    req: Request
    gamma: int
    tree: tree_mod.TokenTree
    fused_t: np.ndarray                  # (gamma,) fused main chain
    fused_p: np.ndarray                  # (gamma,) fused confidences
    d_toks: np.ndarray                   # (N, gamma)
    d_confs: np.ndarray                  # (N, gamma)
    d_chains: np.ndarray                 # (N, gamma)
    parts: List[int]
    assumed: Optional[List[int]] = None


class SpeculativeEngine:
    """The serving engine: admission, routing, drafting cohorts,
    tree verification, acceptance and commit over an execution
    backend (policy here, mechanism in `serving.backend` —
    DESIGN.md §2.7). `strategy` picks the serving flow (`STRATEGIES`):
    plain AR, SpecInfer fan-out, PipeInfer, or CoSine's routed
    collaborative drafting."""

    def __init__(self, target: Tuple[ModelConfig, dict],
                 drafters: Sequence[Tuple[ModelConfig, dict, str]],
                 cosine: CoSineConfig, strategy: str = "cosine",
                 latency: Optional[LatencyModel] = None,
                 max_len: int = 512, seed: int = 0,
                 eos_token: Optional[int] = None,
                 drafter_profiles: Optional[Sequence[DrafterProfile]] = None,
                 backend=None):
        assert strategy in STRATEGIES, strategy
        self.strategy = strategy
        self.cfg = cosine
        self.eos = eos_token
        self.seed = seed
        self.target_cfg = target[0]
        # weight-only drafter quantization (DESIGN.md §2.9): resolve each
        # node's mode (ModelConfig.quant overrides the pool-wide
        # cosine.drafter_quant default) and calibrate-and-swap int8
        # params BEFORE the backend builds its runners, so the jitted
        # step functions key on the quantized pytree structure. Only
        # drafts change: the target's accept/correct walk keeps
        # committed streams greedy-exact.
        drafters = resolve_drafter_quant(list(drafters),
                                         cosine.drafter_quant)
        # engine/backend split (DESIGN.md §2.7): the backend owns the
        # runners, the caches and the serving clock; `backend` is "sim"
        # (default — the discrete-event seed behaviour), "async" (the
        # wall-clock AsyncJaxBackend) or a ready ExecutionBackend.
        # `self.target`/`self.drafters` stay as runner aliases for
        # calibration and tests; the serving path goes through
        # `self.backend` only.
        self.backend: ExecutionBackend = make_backend(
            backend, target, drafters, max_len,
            paged=cosine.paged_pool, page_size=cosine.page_size,
            pool_pages=cosine.pool_pages)
        self.backend.bind(self)
        self.target = self.backend.target
        self.drafters = self.backend.drafters
        self.drafter_domains = [d for _, _, d in drafters]
        self.lat = latency or LatencyModel()
        self.pool = RequestPool()
        # telemetry (DESIGN.md §2.6): one registry + tracer per engine;
        # the controllers share the registry's decision log
        self.metrics = MetricsRegistry()
        self.tracer = Tracer(enabled=cosine.enable_tracing,
                             max_spans=cosine.obs_max_events)
        self.router = AdaptiveRouter(len(self.drafters), cosine,
                                     self.target.embed_np, seed)
        self.sched = RequestScheduler(cosine, self.lat,
                                      decisions=self.metrics.decisions)
        self.admission = (AdmissionController(
            cosine, self.lat, decisions=self.metrics.decisions)
            if cosine.enable_admission else None)
        self.stats = ServeStats(metrics=self.metrics)
        self.clock_ms = 0.0
        self._cohort_seq = 0
        self.entry_logits: Dict[int, np.ndarray] = {}
        # rid -> simulated time its current committed context exists from
        # (arrival, then each commit); drafting a request earlier would
        # violate causality in the event timeline
        self.avail_ms: Dict[int, float] = {}
        self.rng = np.random.default_rng(seed)
        # heterogeneous cluster personalities (per-drafter stage clocks,
        # DESIGN.md §2.4); default is the seed's homogeneous behaviour,
        # except that int8 weight-only nodes default to the faster
        # INT8_DRAFT_SPEED pace (calibrated_profiles() then recovers the
        # realized pace from measured per-cohort step times)
        self.drafter_profiles = (tuple(drafter_profiles) if drafter_profiles
                                 else pool_profiles(
                                     [c for c, _, _ in drafters]))
        assert len(self.drafter_profiles) == len(self.drafters)
        # SSM/hybrid verifiers cannot apply tree masks -> chain-only trees
        self.tree_capable = self.target_cfg.family not in ("ssm", "hybrid")
        # streaming hook: called as on_commit(request, tokens, now_ms)
        # after every commit (request.done already reflects completion)
        self.on_commit: Optional[Callable] = None
        # wall-clock backends commit the target cache asynchronously on
        # the verification server; the returned tail logits are only
        # consumed by the *next* acceptance walk, so they resolve lazily
        self._tails_fut = None
        if self.backend.is_wallclock:
            assert strategy != "ar", \
                "async backend serves speculative strategies; use the " \
                "simulated backend for the ar baseline"
            from repro.serving.async_loop import WallClockExecutor
            self.executor = WallClockExecutor(
                self, overlap=strategy in PIPELINED_STRATEGIES)
        elif strategy in PIPELINED_STRATEGIES:
            from repro.serving.pipeline import PipelineExecutor
            self.executor = PipelineExecutor(self)
        else:
            self.executor = None

    # ------------------------------------------------------------ requests
    def submit(self, prompt, max_new_tokens: int = 32, domain=None,
               arrival_ms: float = 0.0, priority: int = 1,
               slo_ms: Optional[float] = None) -> Request:
        """slo_ms: per-request latency budget (deadline = arrival + slo);
        defaults to cfg.default_slo_ms. priority: class (0 high, 1
        normal, 2 low) consumed by the scheduler's aging credit and the
        admission layer's shed/preempt ordering."""
        budget = self.cfg.default_slo_ms if slo_ms is None else slo_ms
        r = self.pool.add(prompt, max_new_tokens, domain, arrival_ms,
                          deadline_ms=arrival_ms + budget,
                          priority=priority)
        r.gamma = self.cfg.draft_len
        self.avail_ms[r.rid] = arrival_ms
        self.tracer.mark("arrival", r.rid, arrival_ms, priority=priority,
                         deadline_ms=r.deadline_ms,
                         max_new_tokens=max_new_tokens)
        return r

    def _next_cohort(self) -> int:
        """Engine-global cohort sequence number (trace/decision join
        key); monotone in host execution order, so deterministic."""
        c = self._cohort_seq
        self._cohort_seq += 1
        return c

    # ----------------------------------------------------------- admission
    def _shed(self, r: Request, now_ms: float):
        """Admission rejected `r`: account it and release any state it
        held. Only zero-token requests are ever shed (the pool asserts),
        so nothing half-committed can leak out."""
        self.pool.shed_request(r.rid, now_ms)
        self.stats.note_shed()
        self.tracer.mark("shed", r.rid, now_ms)
        # unconditional: a no-op for never-prefilled rids, and under the
        # async backend it also cleans a slot a still-queued burst
        # prefill may be about to admit (the drop serializes behind it)
        self.backend.drop_request(r.rid)
        self.entry_logits.pop(r.rid, None)
        self.avail_ms.pop(r.rid, None)
        self.router.drop(r.rid)
        if self.executor is not None:
            self.executor.note_dropped(r.rid)

    def _preempt(self, r: Request, now_ms: float = 0.0):
        """Evict a lower-priority request's slots (admission preemption).
        Its committed stream stays intact in the pool; re-admission goes
        through `_ensure_prefilled`, which re-prefills prompt+generated
        (paying that prefill on the verify stage) — the cheap slot
        evict/re-admit path."""
        self.backend.drop_request(r.rid)
        self.entry_logits.pop(r.rid, None)
        if self.executor is not None:
            self.executor.note_dropped(r.rid)
        r.n_preemptions += 1
        self.stats.note_preempt()
        self.tracer.mark("preempt", r.rid, now_ms,
                         n_generated=len(r.generated))

    def _apply_admission(self, cands: List[Request], now_ms: float,
                         observation: Optional[PipelineObservation],
                         inflight_rids=frozenset(),
                         pipe_empty: bool = False) -> List[Request]:
        """Run the admission layer over the cohort candidates. Requests
        in the in-flight verification cohort are auto-admitted (their
        commit is imminent — shedding or preempting them would
        half-commit a stream); everything else may be queued, shed, or
        trigger a priority preemption."""
        if self.admission is None:
            return cands
        auto = [r for r in cands if r.rid in inflight_rids]
        rest = [r for r in cands if r.rid not in inflight_rids]
        active = [r for r in self.pool.pending(float("inf"))
                  if r.rid in self.entry_logits
                  and r.rid not in inflight_rids]
        dec = self.admission.decide(
            rest, now_ms, observation=observation, active=active,
            n_protected=len(inflight_rids), pipe_empty=pipe_empty)
        for r in dec.shed:
            self._shed(r, now_ms)
        preempted = {r.rid for r in dec.preempt}
        for r in dec.preempt:
            self._preempt(r, now_ms)
        return auto + [r for r in dec.admit if r.rid not in preempted]

    def _ensure_prefilled(self, r: Request, now_ms: Optional[float] = None):
        if r.rid in self.entry_logits:
            return
        if r.n_preemptions > 0 and r.generated:
            # a preemption victim re-entering: its re-prefill is charged
            # by the caller; the lifecycle track records the re-admission
            self.tracer.mark(
                "readmit", r.rid,
                self.clock_ms if now_ms is None else now_ms)
        ctx = list(r.prompt) + r.generated
        res = self.backend.prefill_target({r.rid: ctx})
        self.entry_logits[r.rid] = res[r.rid][0]
        if self.strategy != "ar":
            # drafters stay one token behind the committed stream so the
            # draft loop's first decode(prev) feeds ctx[-1] exactly once
            # (an empty d_ctx — single-token prompt — admits a bare slot)
            lls = self.backend.prefill_drafters({r.rid: ctx[:-1]})[r.rid]
            if self.strategy == "cosine" and self.cfg.enable_routing:
                # content-based routing prior (paper §5 request analysis)
                self.router.set_prior(r.rid, lls)

    def _ensure_prefilled_batch(self, rs: List[Request],
                                now_of: Optional[Dict[int, float]] = None):
        """Burst admission (DESIGN.md §2.7): prefill several cold
        requests through one masked `slot_extend` write per model when
        `cfg.batched_prefill` is on; otherwise the per-request path in
        submission order (the seed's byte-identical behaviour). Timing
        is charged by the caller either way — this only batches the
        token computation."""
        rs = [r for r in rs if r.rid not in self.entry_logits]
        if not rs:
            return
        now_of = now_of or {}
        if not self.cfg.batched_prefill or len(rs) == 1:
            for r in rs:
                self._ensure_prefilled(r, now_ms=now_of.get(r.rid))
            return
        for r in rs:
            if r.n_preemptions > 0 and r.generated:
                self.tracer.mark("readmit", r.rid,
                                 now_of.get(r.rid, self.clock_ms))
        ctxs = {r.rid: list(r.prompt) + r.generated for r in rs}
        res = self.backend.prefill_target(ctxs, batched=True)
        for rid, (lg, _) in res.items():
            self.entry_logits[rid] = lg
        if self.strategy != "ar":
            d_ctx = {rid: c[:-1] for rid, c in ctxs.items()}
            lls = self.backend.prefill_drafters(d_ctx, batched=True)
            if self.strategy == "cosine" and self.cfg.enable_routing:
                for rid in ctxs:
                    self.router.set_prior(rid, lls[rid])

    # ------------------------------------------------------------ planning
    def _plan_cohort(self, cands: List[Request],
                     observation: Optional[PipelineObservation] = None,
                     extra_ctx: Optional[Dict[int, int]] = None,
                     now_ms: float = 0.0):
        """Pick (batch, gammas) for one iteration. cosine solves Eq. (8);
        the baselines batch FIFO with a fixed draft length."""
        if self.strategy == "cosine":
            plan = self.sched.plan(
                cands, pipelined=self.executor is not None,
                n_drafters=self.cfg.drafters_per_request,
                n_nodes=len(self.drafters),
                observation=observation, extra_ctx=extra_ctx,
                now_ms=now_ms)
            return plan.requests, plan.gammas
        batch = sorted(cands, key=lambda r: r.arrival_ms)[: self.cfg.max_batch]
        return batch, [self.cfg.draft_len] * len(batch)

    def _cohort_gammas(self, reqs: List[Request]) -> List[int]:
        """Draft lengths for a redraft cohort (no re-planning)."""
        if self.strategy == "cosine":
            return adaptive_speculation([r.gamma for r in reqs],
                                        self.cfg.gamma_max_total,
                                        self.cfg.min_gamma)
        return [self.cfg.draft_len] * len(reqs)

    # ------------------------------------------------------------ drafting
    def _participants(self, r: Request) -> List[int]:
        n = len(self.drafters)
        if self.strategy == "cosine":
            if not self.cfg.enable_routing:   # ablation: random assignment
                k = min(self.cfg.drafters_per_request, n)
                return sorted(self.rng.choice(n, size=k, replace=False).tolist())
            return self.router.route(r.rid, r.l_acc_ema)
        if self.strategy == "specinfer":
            return list(range(n))
        return [0]

    def draft_batch(self, parts: List[List[int]], b: int) -> int:
        """Drafting batch the analytic cost should charge: the most
        loaded node's routed sub-batch size (the lock-step pace setter),
        or the cohort width under the legacy full fan-out."""
        if not self.cfg.subbatch_drafting or not parts:
            return b
        counts: Dict[int, int] = {}
        for p in parts:
            for di in p:
                counts[di] = counts.get(di, 0) + 1
        return max(counts.values(), default=b)

    def n_active(self, entries: List[DraftEntry]) -> int:
        """Drafters concurrently active per request under `strategy`."""
        if self.strategy == "cosine":
            mean = sum(len(e.parts) for e in entries) / max(len(entries), 1)
            return max(int(np.ceil(mean)), 1)
        return len(self.drafters) if self.strategy == "specinfer" else 1

    def _build_entry_tree(self, chain_t, chain_p, d_toks, d_confs,
                          parts, g: int) -> tree_mod.TokenTree:
        """Tree for one request: fused main chain + per-drafter side
        branches (cosine), full specinfer tree, or a bare chain."""
        N = len(self.drafters)
        if self.strategy == "cosine" and self.tree_capable \
                and self.cfg.tree_width > 0:
            side_p = np.where(np.isin(np.arange(N), parts), d_confs.T, -1.0)
            side_d = np.broadcast_to(np.arange(N), (g, N))
            return tree_mod.build_tree(chain_t, chain_p, d_toks.T, side_p,
                                       side_d, self.cfg.tree_width)
        if self.strategy == "specinfer" and self.tree_capable:
            return tree_mod.build_tree(
                chain_t, chain_p, d_toks.T, d_confs.T,
                np.broadcast_to(np.arange(N), (g, N)),
                tree_width=max(N - 1, 1))
        return tree_mod.chain_tree(chain_t, chain_p)

    def _draft_entries(self, batch: List[Request], gammas: List[int],
                       optimistic: Optional[Dict[int, np.ndarray]] = None,
                       parts: Optional[List[List[int]]] = None,
                       roles: Optional[Dict[int, str]] = None
                       ) -> List[DraftEntry]:
        """Draft one cohort. `optimistic[rid]` is an (N, n) matrix of
        per-drafter chain tokens assumed to already extend rid's committed
        context (draft-ahead); requests are grouped by assumption width so
        teacher-forcing shapes stay exact (SSM-state safe).

        parts/roles: precomputed per-request participants and per-node
        cluster roles ("fused"/"side"/"dropped") from the drafter
        cluster's timing plan (DESIGN.md §2.4); None means every
        participant is on time (the coupled baselines)."""
        optimistic = optimistic or {}
        groups: Dict[int, List[int]] = {}
        for i, r in enumerate(batch):
            n = optimistic[r.rid].shape[1] if r.rid in optimistic else 0
            groups.setdefault(n, []).append(i)
        entries: List[Optional[DraftEntry]] = [None] * len(batch)
        for n, idxs in sorted(groups.items()):
            sub = [batch[i] for i in idxs]
            sub_g = [gammas[i] for i in idxs]
            sub_p = [parts[i] for i in idxs] if parts is not None else None
            teach = None
            if n:
                teach = np.stack([optimistic[r.rid] for r in sub], axis=1)
            for i, e in zip(idxs, self._draft_group(sub, sub_g, teach,
                                                    parts=sub_p,
                                                    roles=roles)):
                entries[i] = e
        return entries  # type: ignore[return-value]

    def _draft_group(self, batch: List[Request], gammas: List[int],
                     teach: Optional[np.ndarray] = None,
                     parts: Optional[List[List[int]]] = None,
                     roles: Optional[Dict[int, str]] = None
                     ) -> List[DraftEntry]:
        """Run the speculation cluster for one cohort (shared batch shape).

        Route-faithful sub-batching (DESIGN.md §2.4): each drafter node
        decodes only the requests routed to it. Per-node index maps
        (`rows_of[di]` = cohort positions, in cohort order) slice the slot
        snapshots, the teacher-forcing matrices and the K-step loop down
        to each node's sub-batch, so drafter compute scales with
        sum(|sub-batch|) ~= k*B — the timing `DrafterCluster.plan_cohort`
        already charges — instead of the SpecInfer-style N*B fan-out.
        Sub-batch shapes are bucketed by the runner (`slot_bucket`), so
        ragged per-node sizes stay within the bounded compile set. With
        `cfg.subbatch_drafting=False` (or specinfer, where every node is
        routed everything) every node decodes the whole cohort — the
        legacy full fan-out, kept token-identical (tested).

        teach: (N, B, n) per-drafter tokens to teacher-force into the slot
        snapshots before drafting (the optimistic context extension)."""
        B, K, N = len(batch), max(gammas), len(self.drafters)
        rids = [r.rid for r in batch]
        if parts is None:
            parts = [self._participants(r) for r in batch]
        roles = roles or {}
        # cluster roles (DESIGN.md §2.4): only on-time ("fused") nodes
        # take part in per-step confidence fusion; cut nodes run free on
        # their own chains. A request whose participants were all cut
        # falls back to fusing over them (degenerate local quorum).
        fuse_cand = [[i for i in p if roles.get(i, "fused") == "fused"] or p
                     for p in parts]
        # chains delivered to the server: everything not dropped
        delivered = [[i for i in p if roles.get(i, "fused") != "dropped"]
                     or fc for p, fc in zip(parts, fuse_cand)]
        fuse = self.strategy == "cosine" and self.cfg.enable_fusion

        # per-node index maps: rid -> sub-batch position is implied by
        # cohort order, so rows_of[di][j] is the cohort row of node di's
        # j-th sub-batch member
        if self.cfg.subbatch_drafting:
            active = sorted({i for p in parts for i in p})
            rows_of = {di: np.asarray([b for b in range(B) if di in parts[b]],
                                      np.int64) for di in active}
        else:
            active = list(range(N))
            rows_of = {di: np.arange(B, dtype=np.int64) for di in active}

        # slot-snapshot drafting: one device-side gather per node covering
        # only its routed rids; the snapshots are decoded on and then
        # discarded (= rollback) — the slot-resident caches only advance
        # at commit time.
        temp = {di: self.backend.draft_snapshot(
            di, [rids[b] for b in rows_of[di]]) for di in active}

        prev_last = np.array([(r.generated[-1] if r.generated
                               else int(r.prompt[-1])) for r in batch],
                             np.int32)
        prev_node: Dict[int, np.ndarray] = {}
        for di in active:
            rows = rows_of[di]
            if teach is None:
                prev_node[di] = prev_last[rows].copy()
            else:
                # drafter snapshots hold committed[:-1]; replay the last
                # committed token plus the assumed chain (minus its tail,
                # which becomes the next decode input) to reach the
                # optimistic state — sliced to this node's sub-batch
                t_rows = teach[di][rows]
                feed = np.concatenate([prev_last[rows][:, None],
                                       t_rows[:, :-1]], axis=1)
                temp[di] = self.backend.draft_extend(di, temp[di], feed)
                prev_node[di] = t_rows[:, -1].astype(np.int32).copy()

        # drafter-compute accounting: each node pays K steps over its own
        # sub-batch (the quantity the fig7 draft_calls column reports)
        for di in active:
            self.stats.note_draft_work(di, N, K * len(rows_of[di]))

        all_tokens = np.zeros((N, B, K), np.int32)
        all_confs = np.zeros((N, B, K), np.float32)
        d_chains = np.zeros((N, B, K), np.int32)
        chain_tokens = np.zeros((B, K), np.int32)
        chain_probs = np.zeros((B, K), np.float32)

        for i in range(K):
            step_tokens = np.zeros((N, B), np.int32)
            step_confs = np.full((N, B), -1.0, np.float32)
            for di in active:
                rows = rows_of[di]
                lg, temp[di] = self.backend.draft_decode(
                    di, [rids[b] for b in rows], prev_node[di], temp[di])
                probs = jax.nn.softmax(jnp.asarray(lg), -1)
                tok = np.asarray(jnp.argmax(probs, -1))
                conf = np.asarray(jnp.take_along_axis(
                    probs, jnp.asarray(tok)[:, None], -1))[:, 0]
                step_tokens[di, rows] = tok
                step_confs[di, rows] = conf
            all_tokens[:, :, i] = step_tokens
            all_confs[:, :, i] = np.maximum(step_confs, 0.0)

            # confidence-based token fusion (Eq. 4), per request over only
            # that request's on-time participants
            fused = np.zeros(B, np.int32)
            fused_p = np.zeros(B, np.float32)
            for b in range(B):
                cand = fuse_cand[b]
                masked = np.full(N, -1.0)
                masked[cand] = step_confs[cand, b]
                best = int(np.argmax(masked))
                fused[b] = step_tokens[best, b]
                fused_p[b] = max(masked[best], 0.0)
            chain_tokens[:, i] = fused
            chain_probs[:, i] = fused_p

            for di in active:
                rows = rows_of[di]
                if fuse:
                    # cut nodes are out of the per-step sync: they chain
                    # on their own proposals, not the fused token
                    if roles.get(di, "fused") == "fused":
                        prev_node[di] = fused[rows].copy()
                    else:
                        prev_node[di] = step_tokens[di, rows].copy()
                elif self.strategy in ("specinfer", "cosine"):
                    # independent chains (SpecInfer; no-fusion ablation)
                    prev_node[di] = step_tokens[di, rows].copy()
                else:  # single-drafter chain
                    prev_node[di] = step_tokens[0, rows].copy()
                d_chains[di, rows, i] = prev_node[di]

        # (node, request) pairs outside the routed sub-batches consumed no
        # tokens; their teacher-forcing script is the fused chain — the
        # context extension the pending commit is assumed to add — which
        # is exactly what a fused-role node consumes under fusion, so a
        # node joining a request's participants next cohort warms up on
        # the assumed committed stream
        covered = np.zeros((N, B), bool)
        for di in active:
            covered[di, rows_of[di]] = True
        ni, bi = np.nonzero(~covered)
        d_chains[ni, bi, :] = chain_tokens[bi, :]

        out = []
        for b, r in enumerate(batch):
            g = gammas[b]
            # the token tree only carries chains that physically reached
            # the server (fused + in-grace side chains); dropped chains
            # contribute neither branches nor routing evidence
            tree = self._build_entry_tree(
                chain_tokens[b, :g], chain_probs[b, :g],
                all_tokens[:, b, :g], all_confs[:, b, :g], delivered[b], g)
            out.append(DraftEntry(
                req=r, gamma=g, tree=tree,
                fused_t=chain_tokens[b, :g].copy(),
                fused_p=chain_probs[b, :g].copy(),
                d_toks=all_tokens[:, b, :g].copy(),
                d_confs=all_confs[:, b, :g].copy(),
                d_chains=d_chains[:, b, :g].copy(),
                parts=delivered[b]))
        return out

    def _shift_entry(self, e: DraftEntry) -> Optional[DraftEntry]:
        """A surviving draft-ahead entry: its first fused token was just
        committed as the verifier's correction token, so the remaining
        chain is a valid draft on the new committed state."""
        g = e.gamma - 1
        if g < 1:
            return None
        tree = self._build_entry_tree(e.fused_t[1:], e.fused_p[1:],
                                      e.d_toks[:, 1:], e.d_confs[:, 1:],
                                      e.parts, g)
        return DraftEntry(req=e.req, gamma=g, tree=tree,
                          fused_t=e.fused_t[1:], fused_p=e.fused_p[1:],
                          d_toks=e.d_toks[:, 1:], d_confs=e.d_confs[:, 1:],
                          d_chains=e.d_chains[:, 1:], parts=e.parts)

    # ------------------------------------------------------------ verify
    def _verify_dispatch(self, entries: List[DraftEntry]) -> VerifyHandle:
        """Start the batched tree-verification forward for a cohort. On
        the simulated backend the forward runs synchronously here; on the
        async backend it is in flight on the verification server while
        the caller drafts ahead."""
        trees = [e.tree for e in entries]
        M_nodes = max(t.n_nodes for t in trees)
        padded = tree_mod.pad_trees(trees, M_nodes)
        rids = [e.req.rid for e in entries]
        return self.backend.verify_dispatch(rids, padded["tokens"],
                                            padded["rel_pos"],
                                            padded["mask"])

    def _resolve_tails(self) -> None:
        """Land the pending async commit's tail logits. Rids that left
        the engine since the commit was queued (completed, shed or
        preempted — their entry_logits entry was popped) are skipped so
        a stale tail can never resurrect a dropped request's state."""
        fut = self._tails_fut
        if fut is None:
            return
        self._tails_fut = None
        for rid, lg in fut.result().items():
            if rid in self.entry_logits:
                self.entry_logits[rid] = np.asarray(lg)

    def _verify_commit(self, entries: List[DraftEntry],
                       handle: Optional[VerifyHandle] = None):
        """Batched tree verification + commit: greedy acceptance walk,
        router update, cache extension (target exact, drafters one-behind)
        and tail entry logits. Returns (committed, total_committed).

        `handle` carries an already-dispatched verification (wall-clock
        pipelining); without one the forward is dispatched inline — the
        seed's synchronous call order."""
        batch = [e.req for e in entries]
        trees = [e.tree for e in entries]
        if handle is None:
            handle = self._verify_dispatch(entries)
        node_logits = handle.result()
        # previous commit's tail logits must land before the walk below
        # reads entry_logits (async backends defer the commit forward)
        self._resolve_tails()

        prev_last = {r.rid: (r.generated[-1] if r.generated
                             else int(r.prompt[-1])) for r in batch}
        committed: Dict[int, List[int]] = {}
        total_committed = 0
        for b, (e, r) in enumerate(zip(entries, batch)):
            t = trees[b]
            node_argmax = np.argmax(node_logits[b, : t.n_nodes], -1)
            entry_argmax = int(np.argmax(self.entry_logits[r.rid]))
            acc_tokens, acc_nodes, correction = tree_mod.accept_tree_greedy(
                t, node_argmax, entry_argmax)
            toks = acc_tokens + [int(correction)]
            remaining = r.max_new_tokens - len(r.generated)
            toks = toks[: max(remaining, 1)]
            if self.eos is not None and self.eos in toks:
                toks = toks[: toks.index(self.eos) + 1]
            committed[r.rid] = toks
            total_committed += len(toks)
            r.record_acceptance(len(toks), e.gamma)
            # routing update (Eq. 1-2) from this iteration's evidence
            if self.strategy == "cosine":
                self.router.update(r.rid, e.d_toks, e.d_confs, toks, e.parts)

        # ---- commit to target + drafters ----
        if self.backend.is_wallclock:
            # queue the commit forward on the verification server: it
            # overlaps the drafter commit + next draft on this thread,
            # and worker FIFO order guarantees it lands in the target
            # cache before the next verification reads the slots
            self._tails_fut = self.backend.commit_target_async(committed)
        else:
            tails = self.backend.commit_target(committed)
            for rid, lg in tails.items():
                self.entry_logits[rid] = lg
        if self.drafters:
            # one-behind invariant: drafters absorb the previously-held-back
            # token plus all but the last newly committed one
            d_committed = {rid: [prev_last[rid]] + toks[:-1]
                           for rid, toks in committed.items()}
            self.backend.commit_drafters(d_committed)
        return committed, total_committed

    # ------------------------------------------------------------ one step
    def step(self) -> Optional[IterationRecord]:
        """One serving iteration (delegates to the pipelined executor
        when the strategy decouples draft/verify); None when drained."""
        if self.executor is not None:
            return self.executor.step()

        pending = self.pool.pending(self.clock_ms)
        if not pending:
            future = [r.arrival_ms for r in self.pool.pending(float("inf"))]
            if not future:
                return None
            self.clock_ms = min(future)   # idle until next arrival
            pending = self.pool.pending(self.clock_ms)

        # admission (coupled path): the synchronous engine has no event
        # timeline, so saturation is proxied by the backlog exceeding
        # what one batch can hold
        if self.admission is not None:
            obs = PipelineObservation(
                queue_depth=1 if len(pending) > self.cfg.max_batch else 0,
                backlog=len(pending))
            pending = self._apply_admission(
                pending, self.clock_ms, obs,
                pipe_empty=not self.stats.records)
            if not pending:
                return self.step() if self.pool.pending(float("inf")) \
                    else None

        # cold requests pay their prompt forward on the same server the
        # pipelined strategies do (serialized prefill jobs) — TTFT is
        # apples-to-apples across all five strategies (ROADMAP item)
        cold = [r for r in pending if r.rid not in self.entry_logits]
        t_pf = sum(self.lat.t_prefill(r.context_len) for r in cold)
        self._ensure_prefilled_batch(pending)

        if self.strategy == "ar":
            return self._step_ar(pending, t_pf)
        return self._step_coupled(pending, t_pf)

    def _trace_coupled_record(self, rec: IterationRecord,
                              rids: Tuple[int, ...]):
        """Analytic-decomposition spans for the coupled baselines: the
        verifier provably idles through draft + communication, so the
        verify track tiles prefill → bubble(draft) → verify and the
        aggregate draft track carries one draft span — the same schema
        the pipelined strategies emit from their stage clocks, so the
        export works for all five strategies."""
        tr = self.tracer
        if not tr.enabled:
            return
        t0, c = rec.t_start_ms, rec.cohort
        if rec.prefill_ms > 0:
            tr.span("prefill", STAGE, VERIFY, t0, t0 + rec.prefill_ms,
                    cohort=c, rids=rids)
        if rec.draft_ms > 0:
            tr.span("draft", STAGE, DRAFT, rec.draft_start_ms,
                    rec.draft_start_ms + rec.draft_ms, cohort=c, rids=rids)
        if rec.verify_idle_ms > 0:
            tr.span("bubble", STAGE, VERIFY, t0 + rec.prefill_ms,
                    t0 + rec.prefill_ms + rec.verify_idle_ms,
                    cohort=c, rids=rids, cause="draft")
        tr.span("verify", STAGE, VERIFY, rec.verify_start_ms,
                rec.verify_start_ms + rec.verify_ms, cohort=c, rids=rids)

    def _step_coupled(self, pending: List[Request],
                      prefill_ms: float = 0.0) -> IterationRecord:
        batch, gammas = self._plan_cohort(pending, now_ms=self.clock_ms)
        parts = [self._participants(r) for r in batch]
        entries = self._draft_entries(batch, gammas, parts=parts)
        committed, total_committed = self._verify_commit(entries)

        b = len(batch)
        l = max(r.context_len for r in batch)
        gmax = max(gammas)
        big_gamma = sum(e.tree.n_nodes for e in entries)
        n_active = self.n_active(entries)
        # drafting cost is paid on the routed sub-batches: the lock-step
        # cluster advances at its most loaded node, not the cohort width
        b_draft = self.draft_batch(parts, b)
        t_ssm = self.lat.t_ssm(b_draft, l, gmax, n_active)
        t_llm = self.lat.t_llm(b, l, big_gamma)
        t_iter = self.lat.iteration_coupled(b, l, gmax, big_gamma, n_active,
                                            prefill_ms=prefill_ms,
                                            draft_b=b_draft)
        rec = IterationRecord(
            self.clock_ms, t_iter, b, big_gamma, total_committed, n_active,
            cohort=self._next_cohort(),
            draft_start_ms=self.clock_ms + prefill_ms, draft_ms=t_ssm,
            verify_start_ms=self.clock_ms + prefill_ms + t_ssm
            + self.lat.comm_ms,
            verify_ms=t_llm, prefill_ms=prefill_ms,
            # coupled execution: the verifier provably waits out the whole
            # draft + communication phase every iteration (prefill is
            # server *busy* time, not idle)
            verify_idle_ms=t_ssm + self.lat.comm_ms)
        self._trace_coupled_record(rec, tuple(r.rid for r in batch))
        self._finalize(batch, committed, rec)
        if self.strategy == "cosine":
            busy = t_llm / max(t_iter, 1e-9)
            for e in entries:
                if not e.req.done:
                    self.sched.update_gamma_feedback(
                        e.req, len(committed[e.req.rid]), busy,
                        now_ms=self.clock_ms)
        return rec

    def _step_ar(self, pending: List[Request],
                 prefill_ms: float = 0.0) -> IterationRecord:
        batch = sorted(pending, key=lambda r: r.arrival_ms)[: self.cfg.max_batch]
        committed: Dict[int, List[int]] = {}
        for r in batch:
            tok = int(np.argmax(self.entry_logits[r.rid]))
            committed[r.rid] = [tok]
        tails = self.backend.commit_target(committed)
        for rid, lg in tails.items():
            self.entry_logits[rid] = lg
        b = len(batch)
        l = max(r.context_len for r in batch)
        t_llm = self.lat.t_llm(b, l, b)
        rec = IterationRecord(self.clock_ms, t_llm + prefill_ms, b, b, b, 0,
                              cohort=self._next_cohort(),
                              verify_start_ms=self.clock_ms + prefill_ms,
                              verify_ms=t_llm, prefill_ms=prefill_ms)
        self._trace_coupled_record(rec, tuple(r.rid for r in batch))
        for r in batch:
            r.record_acceptance(1, 0)
        self._finalize(batch, committed, rec)
        return rec

    def _finalize(self, batch, committed, rec: IterationRecord):
        self.clock_ms = rec.t_start_ms + rec.t_iter_ms
        self.stats.add_record(rec)
        if self.admission is not None and rec.committed > 0:
            # measured service-time evidence for the shed test (ms/token
            # under the *current* load, not the analytic optimum)
            self.admission.svc.observe(rec.t_iter_ms, rec.committed,
                                       rec.batch, now_ms=self.clock_ms)
        for r in batch:
            toks = committed[r.rid]
            # commit instant at the iteration's end time — exactly
            # rec.t_start_ms + rec.t_iter_ms (tested against the record)
            self.tracer.mark("commit", r.rid, self.clock_ms,
                             cohort=rec.cohort, n_tokens=len(toks))
            if r.first_token_ms < 0 and toks:
                r.first_token_ms = self.clock_ms
                self.tracer.mark("first_token", r.rid, self.clock_ms,
                                 cohort=rec.cohort)
                self.metrics.observe(
                    "serve.ttft_ms", self.clock_ms - r.arrival_ms)
            r.generated.extend(toks)
            hit_eos = self.eos is not None and self.eos in toks
            if len(r.generated) >= r.max_new_tokens or hit_eos:
                self.pool.finish(r.rid, self.clock_ms)
                self.backend.drop_request(r.rid)
                self.entry_logits.pop(r.rid, None)
                self.avail_ms.pop(r.rid, None)
                self.router.drop(r.rid)
                self.tracer.mark("complete", r.rid, self.clock_ms,
                                 cohort=rec.cohort,
                                 n_generated=len(r.generated))
                self.metrics.inc("serve.completed")
                self.metrics.observe(
                    "serve.request_ms", self.clock_ms - r.arrival_ms)
            else:
                self.avail_ms[r.rid] = self.clock_ms
            if self.on_commit is not None and toks:
                # after completion handling, so a streaming consumer
                # that keys on req.done sees it set on the final commit
                self.on_commit(r, toks, self.clock_ms)

    def run(self, max_iterations: int = 10_000) -> ServeStats:
        """Step until the pool drains; returns the run's ServeStats."""
        for _ in range(max_iterations):
            if self.step() is None:
                break
        return self.stats
