"""Discrete-event decoupled pipeline executor (paper §4.3 Alg. 2,
PipeInfer-style decoupling; DESIGN.md §2).

The speculation side is a *multi-node drafter cluster* — one `StageClock`
per drafter node with its own latency profile (serving/cluster.py,
DESIGN.md §2.4) — feeding a serial verification server:

  drafter nodes (draft0..draftN)  --tokens-->  verification server ("verify")

A cohort fans out across the router-selected nodes, fuses when the
confidence-gated quorum arrives, and cuts stragglers loose (late chains
join the side-branch tree or are dropped — they never block the verify
clock). The cluster drafts cohort i+1 while the server verifies i. For
requests whose iteration-i verification is still in flight, drafting
proceeds *optimistically* on slot snapshots: the drafter state is
teacher-forced over the iteration-i fused chain (assumed fully accepted)
and the chain simply continues. The assumption matrices (`d_chains`,
(N, gamma) per request) are consumed per node: `_draft_group` slices
each node's rows down to its routed sub-batch before teacher-forcing,
and redraft cohorts re-slice against their own (freshly routed) parts.
When the verification lands, each dependent draft is reconciled against
the actually committed tokens:

  * survive — every assumed token was accepted AND the verifier's
    correction token equals the ahead-draft's first fused token; the
    remaining chain (shifted by one) is a valid draft on the new
    committed state and goes to verification as-is.
  * invalidate — anything else; the entry is re-drafted from the real
    committed state (`kind="redraft"` on the draft stage), and the
    verifier's next start is pushed out accordingly. This is the
    pipelined price of a rejection — it shows up as measured bubble
    time, not as a formula term.

Losslessness is preserved unconditionally: every tree that reaches
`_verify_commit` is rooted at the *true* committed context (survivor
shifts included), and greedy tree acceptance + correction token always
commits exactly the target's greedy continuation regardless of what the
drafts contain.

Timing semantics (DESIGN.md §2.2): draft->verify transfers pay
`comm_ms`; verification outcomes stream back to the central node with
the commit decision, so a redraft may begin at the verification's end
time (the return path overlaps the verification tail — sub-ms token
payloads). A cold request's prompt forward is a *prefill job on the
verify stage* (`LatencyModel.t_prefill`) that gates its first draft, so
TTFT includes the cold-start prefill under bursty arrivals. Verifier
idle (bubble) time, queueing, and stage occupancy are all *measured*
off the event timeline; nothing here consults the analytic
`iteration_pipelined` formula.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.scheduler import PipelineObservation
from repro.serving.cluster import DrafterCluster
from repro.serving.events import DRAFT, VERIFY, EventLog, StageClock


@dataclass
class DraftJob:
    """One drafted cohort in flight between the stages."""
    entries: List["DraftEntry"]          # noqa: F821 (engine.DraftEntry)
    draft_start_ms: float
    draft_ms: float
    ready_ms: float                      # arrival at the verification server
    n_active: int
    cohort: int = -1                     # engine-global cohort seq (trace id)
    # per-drafter-node busy time spent on this cohort (draft + redrafts)
    node_busy: Dict[int, float] = field(default_factory=dict)
    n_straggler_side: int = 0
    n_straggler_dropped: int = 0


class PipelineExecutor:
    """Advances one verification commit per `step()` call; the draft
    cluster runs (at most) one cohort ahead of the verifier. Drafting is
    fanned out across the router-selected nodes of a `DrafterCluster`,
    each with its own stage clock and latency profile (DESIGN.md §2.4)."""

    def __init__(self, engine):
        self.eng = engine
        self.tracer = engine.tracer
        self.log = EventLog(max_events=engine.cfg.obs_max_events)
        self.cluster = DrafterCluster(engine.drafter_profiles, engine.lat,
                                      engine.cfg, self.log,
                                      seed=engine.seed, tracer=self.tracer)
        self.verify = StageClock(VERIFY, self.log, self.tracer)
        self.next_job: Optional[DraftJob] = None
        # measured verifier occupancy (EMA) consumed by Alg. 2's adaptive
        # speculation feedback; >1 means drafted work queued at the server
        self.busy_ema = 1.0
        # fused-confidence EMA over committed cohorts: the cluster's
        # dispatch gate (wait for late side chains only when recent
        # speculation has been low-confidence). Starts optimistic.
        self.conf_ema = 1.0
        self.n_survived = 0
        self.n_invalidated = 0
        # prefill time scheduled on the verify stage since the last
        # IterationRecord (attributed to the record that observes it)
        self._prefill_acc_ms = 0.0
        # verify free time *before* the in-flight verification was placed
        # (step() schedules the verification before spawning the ahead
        # cohort, so prefills queue behind it; the queue-depth observation
        # must still compare against the pre-verification free time)
        self._vfree_before = 0.0

    # --------------------------------------------------------------- state
    def note_dropped(self, rid: int) -> None:
        """Shed/preempt notification (the wall-clock executor invalidates
        pending prefills here; the simulated pipeline holds no per-request
        executor state)."""

    def observation(self, backlog: int = 0,
                    waiting: Optional[DraftJob] = None) -> PipelineObservation:
        """`waiting` is a drafted cohort not yet picked up by the server;
        it counts as queue depth only if it reached the server before the
        server freed up from the *previous* verification (i.e. it is
        genuinely sitting in the queue)."""
        queued = 1 if (waiting is not None
                       and waiting.ready_ms < self._vfree_before) else 0
        obs = PipelineObservation(
            verify_busy_frac=self.verify.busy_frac(),
            draft_busy_frac=self.cluster.aggregate_busy_frac(),
            queue_depth=queued,
            backlog=backlog,
            drafter_busy_fracs=self.cluster.busy_fracs(),
            drafter_wait_fracs=self.cluster.wait_fracs(),
            spec_saturated=self.eng.sched.spec_saturated)
        # mirror the measured state into the registry so the metrics
        # export shows what the controllers last saw (DESIGN.md §2.6)
        m = self.eng.metrics
        m.set_gauge("pipeline.verify_busy_frac", obs.verify_busy_frac)
        m.set_gauge("pipeline.draft_busy_frac", obs.draft_busy_frac)
        m.set_gauge("pipeline.queue_depth", obs.queue_depth)
        m.set_gauge("pipeline.backlog", obs.backlog)
        for i, f in enumerate(obs.drafter_busy_fracs):
            m.set_gauge("draft.node_busy_frac", f, node=i)
        return obs

    def _observe_conf(self, entries) -> None:
        """Fold a drafted cohort's fused confidences into the EMA the
        *next* cohort's dispatch gate consumes."""
        conf = float(np.mean(np.concatenate([e.fused_p for e in entries])))
        self.conf_ema = 0.7 * self.conf_ema + 0.3 * conf

    # ------------------------------------------------------------ drafting
    def _spawn_job(self, prev: Optional[DraftJob]) -> Optional[DraftJob]:
        """Draft the next cohort on the draft stage.

        prev is the cohort currently awaiting verification: its requests
        are drafted ahead optimistically (assumed fully accepted). With
        no prev (cold pipe) the cluster idles until the next arrival."""
        eng = self.eng
        inflight = ({e.req.rid: e for e in prev.entries} if prev else {})
        t_vis = self.cluster.horizon_ms()

        def avail(r):
            # an in-flight request's optimistic continuation is legal as
            # soon as its previous draft exists; a fresh request only once
            # its current committed context does (arrival / last commit)
            if r.rid in inflight:
                return r.arrival_ms
            return eng.avail_ms.get(r.rid, r.arrival_ms)

        everyone = eng.pool.pending(float("inf"))
        cands = [r for r in everyone if avail(r) <= t_vis]
        if not cands and prev is None:
            if not everyone:
                return None
            t_vis = min(avail(r) for r in everyone)
            cands = [r for r in everyone if avail(r) <= t_vis]
            self.cluster.park_all(t_vis)  # lull: no work existed, not a bubble

        def opt_ext(r):     # optimistic tokens this commit would add
            e = inflight.get(r.rid)
            return (e.gamma + 1) if e is not None else 0

        # skip requests that (optimistically) complete at the pending
        # commit; if a rejection keeps them alive they re-enter next round
        cands = [r for r in cands
                 if r.rid not in inflight
                 or r.max_new_tokens - len(r.generated) - opt_ext(r) > 0]
        if not cands:
            return None
        # admission control (DESIGN.md §2.5), before any prefill is
        # charged: shed/queue decisions consume the measured saturation
        # state, in-flight requests are auto-admitted (their commit is
        # imminent), and preemption victims release their slots here —
        # their re-admission pays a fresh prefill below once re-admitted
        obs = self.observation(backlog=len(cands), waiting=prev)
        if eng.admission is not None:
            cands = eng._apply_admission(
                cands, t_vis, obs, inflight_rids=frozenset(inflight),
                pipe_empty=prev is None)
            if not cands:
                return None
            obs = self.observation(backlog=len(cands), waiting=prev)
        cohort = eng._next_cohort()
        cold = [r for r in cands if r.rid not in eng.entry_logits]
        for r in cold:
            # cold request: the prompt forward occupies the
            # verification server and gates drafting, so TTFT is
            # honest under bursty arrivals (no free prefills)
            t_pf = eng.lat.t_prefill(r.context_len)
            self.verify.park(avail(r))   # arrival lull != bubble
            _, pend, _ = self.verify.schedule(
                t_pf, not_before_ms=avail(r), kind="prefill",
                rids=(r.rid,), cohort=cohort)
            eng.avail_ms[r.rid] = pend
            self._prefill_acc_ms += t_pf
        eng._ensure_prefilled_batch(
            cold, now_of={r.rid: avail(r) for r in cold})
        extra = {r.rid: opt_ext(r) for r in cands if r.rid in inflight}
        batch, gammas = eng._plan_cohort(
            cands, observation=obs, extra_ctx=extra, now_ms=t_vis)
        optim = {r.rid: inflight[r.rid].d_chains
                 for r in batch if r.rid in inflight}

        K = max(gammas)
        l = max(r.context_len + extra.get(r.rid, 0) for r in batch)
        rids = tuple(r.rid for r in batch)
        # drafting cannot start before every cold member's prefill landed
        # nor before a warm member's context was committed; per-node
        # availability is enforced by the node clocks themselves (the
        # horizon is NOT part of the gate — a cut node running long must
        # never delay the next cohort's on-time nodes)
        gate = max([0.0] + [avail(r) for r in batch
                            if r.rid not in inflight])
        # fan the cohort out across the router-selected drafter nodes:
        # the cluster assigns roles (on-time fused quorum / side / cut)
        # and the confidence-gated dispatch before token drafting — pace
        # depends only on profiles + seeded jitter, and the gate consumes
        # the fused-confidence EMA measured over *previous* cohorts, so
        # nothing about the timing can depend on this cohort's tokens
        parts_by_req = {r.rid: eng._participants(r) for r in batch}
        plan = self.cluster.plan_cohort(parts_by_req, l, K, gate,
                                        conf_signal=self.conf_ema,
                                        release_ms=max(gate, t_vis))
        roles = plan.roles()
        entries = eng._draft_entries(
            batch, gammas, optimistic=optim,
            parts=[plan.parts_by_req[r.rid] for r in batch], roles=roles)
        for e in entries:
            if e.req.rid in optim:
                e.assumed = [int(t) for t in inflight[e.req.rid].fused_t]

        self._observe_conf(entries)
        sched = self.cluster.commit_cohort(plan, rids, kind="draft",
                                           cohort=cohort)
        for node, role in roles.items():
            eng.router.note_node_outcome(node, role)
        n_active = eng.n_active(entries)
        drops = [d.role for d in sched.drafts]
        return DraftJob(entries, sched.start_ms, sched.draft_ms,
                        sched.ready_ms, n_active, cohort=cohort,
                        node_busy=sched.node_busy(),
                        n_straggler_side=drops.count("side"),
                        n_straggler_dropped=drops.count("dropped"))

    # ------------------------------------------------------------ reconcile
    def _reconcile(self, ahead: DraftJob, committed: Dict[int, List[int]],
                   t_known_ms: float) -> Optional[DraftJob]:
        """Resolve the ahead cohort's optimistic assumptions against the
        tokens the verification actually committed. Runs after _finalize,
        so completed requests are marked done and the drafter slot caches
        hold the new committed state for redrafting."""
        eng = self.eng
        keep, redo, invalid = [], [], []
        for e in ahead.entries:
            if e.req.done:
                continue                      # finished at commit: wasted work
            if e.assumed is None:
                keep.append(e)                # was not dependent on the commit
                continue
            toks = committed.get(e.req.rid)
            survives = (toks is not None
                        and len(toks) == len(e.assumed) + 1
                        and toks[:-1] == e.assumed
                        and toks[-1] == int(e.fused_t[0]))
            if survives:
                self.n_survived += 1
                eng.metrics.inc("pipeline.survived")
                shifted = eng._shift_entry(e)
                if shifted is not None:
                    shifted.assumed = None    # now rooted at real state
                    keep.append(shifted)
                else:
                    # gamma==1: the whole ahead draft was consumed by the
                    # commit — a full hit, not an invalidation; it just
                    # needs fresh tokens
                    redo.append(e.req)
            else:
                invalid.append(e.req)
                redo.append(e.req)
        self.n_invalidated += len(invalid)
        ahead.entries = keep
        if invalid:
            self.log.emit(t_known_ms, DRAFT, "invalidate",
                          tuple(r.rid for r in invalid))
            eng.metrics.inc("pipeline.invalidated", len(invalid))
            for r in invalid:
                self.tracer.mark("invalidate", r.rid, t_known_ms,
                                 cohort=ahead.cohort)
        if redo:
            gammas = eng._cohort_gammas(redo)
            K = max(gammas)
            l = max(r.context_len for r in redo)
            parts_by_req = {r.rid: eng._participants(r) for r in redo}
            plan = self.cluster.plan_cohort(parts_by_req, l, K, t_known_ms,
                                            conf_signal=self.conf_ema)
            roles = plan.roles()
            redo_entries = eng._draft_entries(
                redo, gammas,
                parts=[plan.parts_by_req[r.rid] for r in redo], roles=roles)
            self._observe_conf(redo_entries)
            sched = self.cluster.commit_cohort(
                plan, tuple(r.rid for r in redo), kind="redraft",
                cohort=ahead.cohort)
            for node, role in roles.items():
                eng.router.note_node_outcome(node, role)
            n_active = eng.n_active(redo_entries)
            ahead.entries = keep + redo_entries
            ahead.draft_ms += sched.draft_ms
            ahead.ready_ms = max(ahead.ready_ms, sched.ready_ms)
            ahead.n_active = max(ahead.n_active, n_active)
            for node, busy in sched.node_busy().items():
                ahead.node_busy[node] = ahead.node_busy.get(node, 0.0) + busy
            drops = [d.role for d in sched.drafts]
            ahead.n_straggler_side += drops.count("side")
            ahead.n_straggler_dropped += drops.count("dropped")
        if not ahead.entries:
            return None
        return ahead

    # ------------------------------------------------------------ one step
    def step(self):
        """One discrete-event serving iteration on the simulated
        clocks: consume or spawn the draft job, schedule verification
        on the verify StageClock, walk acceptance, commit, and leave
        the next draft-ahead job pending."""
        eng = self.eng
        job, self.next_job = self.next_job, None
        if job is None:
            job = self._spawn_job(None)
            if job is None:
                return None

        # ---- verification ----
        # scheduled *before* the ahead cohort is spawned: new arrivals'
        # prefill jobs then queue behind this already-ready verification
        # instead of preempting it, and its bubble is measured honestly
        batch = [e.req for e in job.entries]
        b = len(batch)
        l = max(r.context_len for r in batch)
        big_gamma = sum(e.tree.n_nodes for e in job.entries)
        t_llm = eng.lat.t_llm(b, l, big_gamma)
        # idle before this cohort's drafting even began is an arrival lull
        # (nothing verifiable could have existed), not a pipeline bubble —
        # the coupled baselines' analytic accounting excludes lulls too
        self.verify.park(job.draft_start_ms)
        vfree0 = self.verify.free_ms
        vstart, vend, bubble = self.verify.schedule(
            t_llm, not_before_ms=job.ready_ms, kind="verify",
            rids=tuple(r.rid for r in batch), cohort=job.cohort,
            cause="await_draft")
        self._vfree_before = vfree0

        # draft-ahead for the next iteration, concurrent with this verify
        ahead = self._spawn_job(job)
        committed, total_committed = eng._verify_commit(job.entries)

        # measured occupancy: wait>0 means the cohort queued at the server
        wait = max(vfree0 - job.ready_ms, 0.0)
        busy_obs = (t_llm + wait) / max(t_llm + bubble, 1e-9)
        self.busy_ema = 0.6 * self.busy_ema + 0.4 * busy_obs

        queue_depth = 1 if (ahead is not None and ahead.ready_ms <= vend) \
            else 0
        from repro.serving.engine import IterationRecord
        # an iteration starts when its cohort's drafting did (arrival
        # lulls sit between records, as in the coupled path's clock jumps)
        t_start = max(eng.clock_ms, job.draft_start_ms)
        rec = IterationRecord(
            t_start_ms=t_start, t_iter_ms=vend - t_start,
            batch=b, big_gamma=big_gamma, committed=total_committed,
            n_active_drafters=job.n_active, cohort=job.cohort,
            draft_start_ms=job.draft_start_ms, draft_ms=job.draft_ms,
            verify_start_ms=vstart, verify_ms=t_llm,
            verify_idle_ms=bubble, prefill_ms=self._prefill_acc_ms,
            queue_depth=queue_depth,
            node_busy_ms=tuple(job.node_busy.get(i, 0.0)
                               for i in range(len(eng.drafters))),
            n_straggler_side=job.n_straggler_side,
            n_straggler_dropped=job.n_straggler_dropped)
        self._prefill_acc_ms = 0.0
        eng._finalize(batch, committed, rec)

        # Alg. 2 adaptive control driven by *observed* occupancy
        if eng.strategy == "cosine":
            for e in job.entries:
                if not e.req.done:
                    eng.sched.update_gamma_feedback(
                        e.req, len(committed[e.req.rid]), self.busy_ema,
                        now_ms=vend)

        # resolve the ahead cohort against what actually committed
        if ahead is not None:
            n_inv0 = self.n_invalidated
            ahead = self._reconcile(ahead, committed, vend)
            rec.n_invalidated = self.n_invalidated - n_inv0
        self.next_job = ahead
        return rec
