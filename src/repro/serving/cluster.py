"""Multi-node drafter cluster: per-drafter stage clocks, quorum fusion,
and straggler cut-off (DESIGN.md §2.4).

The paper's speculation side is a *cluster* of heterogeneous consumer-GPU
nodes, not one serial resource. This module replaces the executor's
single draft `StageClock` with one clock per drafter node, each carrying
its own `DrafterProfile` (speed multiplier, link delay, seeded
jitter/straggler model), so the router's Eq. 3 decisions and the token
fusion of Eq. 4 are exposed to real per-node latency skew.

Cohort semantics (one drafted cohort = one `CohortSchedule`):

  * The participating nodes are split by *pace* into **fused** nodes —
    within `cut_pace_slack` of the fastest node's per-step time — and
    **cut** nodes, whose chains run free at their own pace (they would
    otherwise drag every fused step). Lock-step sync binds only fused
    nodes that *share fused requests*: per-step Eq. 4 fusion exchanges
    tokens within a request's participants, so the fused set is
    partitioned into connected components of the "co-drafts a request"
    graph and each component advances at its own slowest member's pace
    plus a component-sized sync term. Node shapes are the routed
    sub-batches the engine actually decodes (route-faithful drafting —
    see `SpeculativeEngine._draft_group`).
  * Cut chains are never allowed to block the verify clock: a chain
    whose server arrival beats the fused payload rides along for free as
    tree side branches (`role="side"`); the **confidence gate** extends
    that window by the straggler grace — when the engine's recent fused
    confidence (an EMA measured over previous cohorts, so it is known
    *before* drafting) is below `conf_gate`, the cohort waits up to the
    grace for late side chains, buying a wider tree exactly when
    speculation has been missing. Anything later is dropped
    (`role="dropped"`); `straggler_policy="drop"` drops every cut chain.
  * The cohort is ready at the server when the last *included* chain has
    arrived (each chain pays its own link delay exactly once) — a
    dropped straggler can never hold the verifier back, and no token is
    ever verified before its arrival event.

Losslessness is untouched by any of this: roles only shape *which* draft
tokens reach the verifier and *when*; greedy tree acceptance + correction
commits exactly the target's continuation regardless (tested with
extreme stragglers in tests/test_cluster.py).

All jitter/straggle draws come from one `numpy` Generator seeded at
construction and consumed in sorted-node order, so a fixed engine seed
reproduces the per-node event streams byte-for-byte.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.latency_model import DrafterProfile, LatencyModel
from repro.obs.trace import CLUSTER, Tracer
from repro.serving.events import EventLog, StageClock

FUSED = "fused"
SIDE = "side"
DROPPED = "dropped"


@dataclass
class NodeDraft:
    """One node's share of a cohort draft."""
    node: int
    b: int                       # requests routed to this node
    step_ms: float               # per-step pace (profile * jitter, no sync)
    start_ms: float = 0.0
    end_ms: float = 0.0
    arrival_ms: float = 0.0      # chain arrival at the fusion point
    busy_ms: float = 0.0         # time placed on the node's clock
    role: str = FUSED


@dataclass
class CohortSchedule:
    """Timing plan for one cohort across the cluster (built by
    `plan_cohort`, placed on the clocks by `commit_cohort`)."""
    drafts: List[NodeDraft]
    gamma: int
    gate_ms: float
    grace_ms: float
    l: int = 0                   # cohort critical context length (per-job
    #                              pace observations / calibration)
    # when the cohort became runnable (queue-wait accounting only):
    # spawn jobs exist once the previous cohort's drafting finished,
    # redrafts once the rejection outcome is known
    release_ms: float = 0.0
    # per-request participants, possibly augmented by the coverage rider
    # (a request whose drafters were all cut is rerouted to the fastest
    # on-time node — the central scheduler never strands a request on a
    # straggling cluster slice)
    parts_by_req: Dict[int, List[int]] = field(default_factory=dict)
    start_ms: float = 0.0        # earliest node start
    fused_end_ms: float = 0.0    # lock-step group completion
    dispatch_ms: float = 0.0     # confidence-gated ship time
    ready_ms: float = 0.0        # arrival at the verification server
    draft_ms: float = 0.0        # cohort makespan (dispatch - start)
    committed: bool = False

    def roles(self) -> Dict[int, str]:
        """{node: role} for this cohort's dispatched drafts."""
        return {d.node: d.role for d in self.drafts}

    def node_busy(self) -> Dict[int, float]:
        """{node: busy ms} this cohort charged to each node."""
        return {d.node: d.busy_ms for d in self.drafts}


class DrafterCluster:
    """Per-drafter stage clocks plus the quorum/straggler policy.

    The cluster is the *timing* half of multi-node drafting; the token
    half (which proposals fuse, which become side branches, which are
    discarded) is driven by the roles this class assigns — see
    `SpeculativeEngine._draft_group`.
    """

    def __init__(self, profiles: Sequence[DrafterProfile], lat: LatencyModel,
                 cfg, log: Optional[EventLog] = None, seed: int = 0,
                 tracer: Optional[Tracer] = None):
        self.profiles: Tuple[DrafterProfile, ...] = tuple(profiles)
        self.lat = lat
        self.cfg = cfg
        self.log = log
        self.tracer = tracer
        self.nodes = [StageClock(f"draft{i}", log, tracer)
                      for i in range(len(self.profiles))]
        self._rng = np.random.default_rng((seed, 0xC1A5))
        # cumulative straggler accounting (also mirrored per record)
        self.n_cohorts = 0
        self.n_side = 0
        self.n_dropped = 0
        self.node_jobs = [0] * len(self.nodes)
        self.node_late = [0] * len(self.nodes)   # side or dropped episodes
        # per-job pace observations (b, l, step_ms) per node — the raw
        # material for profile auto-calibration (calibrated_profiles)
        self.pace_obs: List[List[Tuple[int, int, float]]] = \
            [[] for _ in self.nodes]

    # ------------------------------------------------------------- state
    def horizon_ms(self) -> float:
        """Candidate-visibility horizon: when the cluster last finished
        drafting (the single-clock executor's `free_ms` equivalent).
        Requests whose context exists by this time are drafteable in the
        next cohort; causality is still enforced per request through the
        cohort gate (cold prefill ends / warm commit times)."""
        return max(n.free_ms for n in self.nodes)

    def park_all(self, t_ms: float):
        """Arrival lull: advance every node clock without accruing idle."""
        for n in self.nodes:
            n.park(t_ms)

    def busy_fracs(self) -> Tuple[float, ...]:
        """Per-node occupancy; a node that never worked reports 0 (it is
        idle capacity, not saturation)."""
        return tuple(n.busy_frac() for n in self.nodes)

    def wait_fracs(self) -> Tuple[float, ...]:
        """Per-node chronic queueing: time jobs spent waiting for the
        node over its active span (0 for an unused node)."""
        out = []
        for n in self.nodes:
            span = n.busy_ms + n.idle_ms
            out.append(n.wait_ms / span if span > 0 else 0.0)
        return tuple(out)

    def aggregate_busy_frac(self) -> float:
        """Cluster-wide occupancy: total busy over total active span."""
        busy = sum(n.busy_ms for n in self.nodes)
        span = sum(n.busy_ms + n.idle_ms for n in self.nodes)
        return busy / span if span > 0 else 1.0

    # ---------------------------------------------------------- planning
    @staticmethod
    def _fused_components(fused: List[int],
                          parts_by_req: Dict[int, List[int]]
                          ) -> List[List[int]]:
        """Partition the on-time nodes into lock-step sync groups: two
        fused nodes synchronise iff they are connected through shared
        fused requests (per-step Eq. 4 fusion only ever exchanges tokens
        within a request's participants, so disjoint sub-batches have
        nothing to wait for)."""
        parent = {i: i for i in fused}

        def find(i):
            while parent[i] != i:
                parent[i] = parent[parent[i]]
                i = parent[i]
            return i

        fused_set = set(fused)
        for p in parts_by_req.values():
            members = [i for i in p if i in fused_set]
            for a, b in zip(members, members[1:]):
                ra, rb = find(a), find(b)
                if ra != rb:
                    parent[ra] = rb
        comps: Dict[int, List[int]] = {}
        for i in fused:
            comps.setdefault(find(i), []).append(i)
        return [sorted(c) for c in sorted(comps.values())]

    def _jitter_mult(self, node: int) -> float:
        """Deterministic seeded jitter/straggle multiplier for one node's
        next job. Both draws are always consumed so the stream position
        is independent of the profile's parameters."""
        p = self.profiles[node]
        z = float(self._rng.standard_normal())
        u = float(self._rng.random())
        mult = math.exp(p.jitter_frac * z)
        if u < p.straggle_prob:
            mult *= p.straggle_factor
        return mult

    def plan_cohort(self, parts_by_req: Dict[int, List[int]], l: int,
                    gamma: int, gate_ms: float,
                    conf_signal: float = 1.0,
                    release_ms: Optional[float] = None) -> CohortSchedule:
        """Assign roles and compute the timing plan for one cohort.

        parts_by_req: rid -> router-selected drafter nodes.
        conf_signal: the engine's recent fused-confidence EMA (measured
        over *previous* cohorts, so roles never depend on this cohort's
        tokens); below `conf_gate` the dispatch waits the grace window
        for late side chains.

        The plan reads the node clocks but does not mutate them;
        `commit_cohort` places the work. Nothing may touch the clocks in
        between (the executor is single-stepped, so nothing does).
        """
        parts_by_req = {rid: list(p) for rid, p in parts_by_req.items()}
        parts = sorted({i for p in parts_by_req.values() for i in p})
        assert parts, "cohort with no participating nodes"
        shapes = {i: sum(1 for p in parts_by_req.values() if i in p)
                  for i in parts}
        mults = {i: self._jitter_mult(i) for i in parts}
        paces = {i: self.lat.ssm_step_node(shapes[i], l, self.profiles[i],
                                           mults[i]) for i in parts}
        fastest = min(paces.values())
        slack = self.cfg.cut_pace_slack
        fused = [i for i in parts if paces[i] <= fastest * slack]
        cut = [i for i in parts if i not in fused]

        # coverage rider: a request whose participants were all cut is
        # rerouted to the fastest on-time node (the central scheduler
        # never strands a request on a straggling cluster slice); its
        # sub-batch grows, so recompute paces — group membership is kept
        # from the pre-rider paces (the batch term is sub-ms)
        fastest_node = min(paces, key=lambda i: paces[i])
        for rid, p in parts_by_req.items():
            if not any(i in fused for i in p):
                p.append(fastest_node)
                shapes[fastest_node] += 1
        paces = {i: self.lat.ssm_step_node(shapes[i], l, self.profiles[i],
                                           mults[i]) for i in parts}

        drafts = {i: NodeDraft(i, shapes[i], paces[i]) for i in parts}
        starts = {i: max(self.nodes[i].free_ms, gate_ms) for i in parts}

        # lock-step sync binds only nodes that actually share fused
        # requests: with route-faithful sub-batches two on-time nodes
        # with disjoint sub-batches never exchange a fused token, so the
        # fused set is partitioned into connected components of the
        # "co-drafts a request" graph and each component advances at its
        # own slowest member's pace (plus a sync term sized to the
        # component, not the whole on-time set)
        max_group_step = 0.0
        for comp in self._fused_components(fused, parts_by_req):
            sync = self.lat.sync_ms(len(comp))
            group_start = max(starts[i] for i in comp)
            group_step = max(paces[i] for i in comp) + sync
            max_group_step = max(max_group_step, group_step)
            group_end = group_start + gamma * group_step
            for i in comp:
                d = drafts[i]
                d.start_ms = starts[i]
                d.end_ms = group_end
                d.busy_ms = group_end - starts[i]  # sync waits occupy the node
                d.arrival_ms = group_end \
                    + self.lat.node_comm_ms(self.profiles[i])
                d.role = FUSED
        # the fused payload is at the server once the slowest fused link
        # has delivered; a cut chain beating that time rides along free
        t_fused_arr = max(drafts[i].arrival_ms for i in fused)
        fused_end = max(drafts[i].end_ms for i in fused)

        grace = self.cfg.straggler_grace_frac * gamma * max_group_step
        policy = self.cfg.straggler_policy
        wait = conf_signal < self.cfg.conf_gate
        deadline = t_fused_arr + (grace if wait else 0.0)
        for i in cut:
            d = drafts[i]
            d.start_ms = starts[i]
            d.busy_ms = gamma * paces[i]        # free-running, no sync
            d.end_ms = starts[i] + d.busy_ms
            d.arrival_ms = d.end_ms + self.lat.node_comm_ms(self.profiles[i])
            in_time = d.arrival_ms <= deadline
            d.role = SIDE if (policy == "side" and in_time) else DROPPED

        included = [d for d in drafts.values() if d.role != DROPPED]
        sched = CohortSchedule(drafts=[drafts[i] for i in parts],
                               gamma=gamma, gate_ms=gate_ms, grace_ms=grace,
                               l=l,
                               release_ms=(gate_ms if release_ms is None
                                           else release_ms),
                               parts_by_req=parts_by_req,
                               start_ms=min(starts[i] for i in parts),
                               fused_end_ms=fused_end,
                               # last included chain leaves its node /
                               # reaches the server (per-link delay paid
                               # exactly once, inside arrival_ms)
                               dispatch_ms=max(d.end_ms for d in included),
                               ready_ms=max(d.arrival_ms for d in included))
        sched.draft_ms = sched.dispatch_ms - sched.start_ms
        return sched

    # ------------------------------------------------------ calibration
    def calibrated_profiles(self, min_jobs: int = 4
                            ) -> Tuple[DrafterProfile, ...]:
        """Fit each node's latency personality from its measured per-job
        paces (fit-style, like `LatencyModel.fit_ssm`).

        Every committed job leaves one observation (b, l, step_ms); the
        ratio of step_ms to the homogeneous step cost at that (b, l) is
        speed * jitter-multiplier, so log-ratios are `log speed` plus the
        lognormal noise. The fit is robust to straggle episodes: speed is
        the exp-median of the log-ratios and jitter_frac the MAD-based
        sigma, so occasional straggles widen jitter instead of biasing
        speed (an always-straggling node honestly calibrates to its
        effective pace). Nodes with fewer than `min_jobs` observations
        keep their configured profile (no evidence, no refit); measured
        straggle episodes are absorbed into the fitted spread, so the
        returned profiles carry straggle_prob=0."""
        base = DrafterProfile()
        out = []
        for node, obs in enumerate(self.pace_obs):
            if len(obs) < min_jobs:
                out.append(self.profiles[node])
                continue
            logr = np.array([math.log(step / self.lat.ssm_step_node(b, l,
                                                                    base))
                             for b, l, step in obs])
            med = float(np.median(logr))
            mad = float(np.median(np.abs(logr - med)))
            out.append(DrafterProfile(
                speed=math.exp(med),
                comm_ms=self.profiles[node].comm_ms,
                jitter_frac=1.4826 * mad))
        return tuple(out)

    # ----------------------------------------------------------- commit
    def commit_cohort(self, sched: CohortSchedule,
                      rids: Tuple[int, ...] = (),
                      kind: str = "draft",
                      cohort: int = -1) -> CohortSchedule:
        """Place the planned cohort on the node clocks (the plan already
        resolved roles, dispatch and ready times — token drafting happens
        between plan and commit and cannot change the timing)."""
        assert not sched.committed
        sched.committed = True
        for d in sched.drafts:
            clk = self.nodes[d.node]
            node_rids = tuple(sorted(
                rid for rid, p in sched.parts_by_req.items() if d.node in p))
            start, end, _ = clk.schedule(
                d.busy_ms, not_before_ms=sched.gate_ms,
                kind=kind if d.role == FUSED else f"{kind}_{d.role}",
                rids=node_rids or rids,
                release_ms=max(sched.gate_ms, sched.release_ms),
                cohort=cohort)
            assert abs(start - d.start_ms) < 1e-9 and abs(end - d.end_ms) < 1e-9
            self.node_jobs[d.node] += 1
            self.pace_obs[d.node].append((d.b, sched.l, d.step_ms))
            if d.role != FUSED:
                self.node_late[d.node] += 1
        if self.tracer is not None and self.tracer.enabled:
            # cluster-level activity lives on its own track: transit can
            # overlap the node's next draft (the link is not the node),
            # so these spans must not break the serial node tracks
            self.tracer.instant("fuse", CLUSTER, "cluster",
                                sched.fused_end_ms, cohort=cohort,
                                rids=rids, kind=kind)
            for d in sched.drafts:
                if d.role == DROPPED:
                    self.tracer.instant("drop", CLUSTER, "cluster",
                                        d.end_ms, cohort=cohort,
                                        node=d.node, kind=kind)
                else:
                    self.tracer.span("transit", CLUSTER, "cluster",
                                     d.end_ms, d.arrival_ms, cohort=cohort,
                                     node=d.node, role=d.role, kind=kind)
        self.n_cohorts += 1
        self.n_side += sum(1 for d in sched.drafts if d.role == SIDE)
        self.n_dropped += sum(1 for d in sched.drafts if d.role == DROPPED)
        if self.log is not None:
            late = tuple(d.node for d in sched.drafts if d.role != FUSED)
            if late:
                self.log.emit(sched.dispatch_ms, "cluster", "straggler_cut",
                              rids, info=",".join(
                                  f"{d.node}:{d.role}" for d in sched.drafts
                                  if d.role != FUSED))
        return sched
