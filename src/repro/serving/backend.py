"""Execution backends: the mechanism half of the engine/backend split
(DESIGN.md §2.7).

`SpeculativeEngine` is *policy* — routing (Eq. 1-3), fusion (Eq. 4),
scheduling (Eq. 5-8/Alg. 2), admission — and an `ExecutionBackend` is
*mechanism*: every model execution (prefill, draft-decode on slot
snapshots, tree verification, cache commit/extend), every cache
admit/evict, and the serving clock. The engine never touches a
`ModelRunner` directly; it speaks this interface, so the same policy
stack runs unchanged against either implementation:

  * `SimulatedBackend` — the seed behaviour: model calls execute
    synchronously on the host in engine order, and time is the
    discrete-event simulated clock (`engine.clock_ms`, advanced by the
    StageClock/EventLog machinery). Every method is a 1:1 pass-through
    to the runners in the exact call order the pre-split engine used,
    so same-seed output (committed tokens, ServeStats, trace export) is
    byte-identical to the monolith (tested in tests/test_backend.py).

  * `AsyncJaxBackend` — a real wall-clock serving loop: the
    verification server is a dedicated worker thread that owns *all*
    target-model device state (verify forwards, prefill writes, commit
    extends, slot drops execute there in FIFO order — no cross-thread
    cache races, and JAX donation stays safe because target dispatches
    are totally ordered), while drafter models run on the engine
    thread. `verify_dispatch` returns immediately with a lazy handle —
    the forward is in flight on the worker (the GIL is released inside
    XLA) while the engine drafts the next cohort — and `device_get` is
    deferred to `VerifyHandle.result()`, so the acceptance walk pays
    the host transfer only when it actually consumes the logits.
    Driven by `serving/async_loop.WallClockExecutor`.

The losslessness contract is backend-independent: both backends execute
identical token-level math, so greedy tree acceptance + correction
always commits exactly the target's greedy continuation.
"""
from __future__ import annotations

import time
from abc import ABC, abstractmethod
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.runner import ModelRunner


class VerifyHandle:
    """Lazy verification result. `result()` materializes the (B, Gmax, V)
    logits on the caller; `times()` reports the measured wall span of the
    forward (None under the simulated backend, where the span lives on
    the simulated verify StageClock instead)."""

    def __init__(self, value: Optional[np.ndarray] = None,
                 future: Optional[Future] = None,
                 convert: Optional[Callable] = None,
                 span: Optional[dict] = None):
        self._value = value
        self._future = future
        self._convert = convert
        self._span = span

    def result(self) -> np.ndarray:
        """Materialize (blocking) and cache the verification logits."""
        if self._value is None:
            raw = self._future.result()
            self._value = self._convert(raw) if self._convert else raw
        return self._value

    def times(self) -> Optional[Tuple[float, float]]:
        """Measured wall (t0, t1) of the forward, or None if simulated."""
        if self._span is None:
            return None
        return self._span["t0"], self._span["t1"]


class ExecutionBackend(ABC):
    """Mechanism interface the engine serves against (DESIGN.md §2.7).

    Implementations own the target and drafter `ModelRunner`s (exposed
    as `.target` / `.drafters` for calibration and tests) plus the
    serving clock. Request-addressed: every method takes rids; slot
    bookkeeping is internal to the runners."""

    target: ModelRunner
    drafters: List[ModelRunner]
    #: True when `now_ms()` is wall time and model calls may be in
    #: flight concurrently (selects the WallClockExecutor)
    is_wallclock = False

    def __init__(self, target, drafter_specs, max_len: int,
                 paged: bool = False, page_size: int = 64,
                 pool_pages: int = 0):
        tcfg, tparams = target
        kw = dict(paged=paged, page_size=page_size, pool_pages=pool_pages)
        self.target = ModelRunner(tcfg, tparams, max_len, **kw)
        self.drafters = [ModelRunner(c, p, max_len, **kw)
                         for c, p, _ in drafter_specs]
        self._engine = None

    def bind(self, engine):
        """Attach the engine (clock source for the simulated backend)."""
        self._engine = engine

    # ------------------------------------------------------------ clock
    @abstractmethod
    def now_ms(self) -> float:
        """Current serving time (simulated or wall, ms)."""

    # ------------------------------------------------- target lifecycle
    @abstractmethod
    def prefill_target(self, reqs: Dict[int, Sequence[int]],
                       batched: bool = False
                       ) -> Dict[int, Tuple[Optional[np.ndarray], float]]:
        """Admit + prefill each request's context on the target; returns
        {rid: (last-position logits, mean next-token logprob)}. With
        `batched`, cold requests share one masked `slot_extend` write
        (burst admission)."""

    @abstractmethod
    def verify_dispatch(self, rids: Sequence[int], tokens: np.ndarray,
                        rel_pos: np.ndarray, seg_mask: np.ndarray
                        ) -> VerifyHandle:
        """Start a tree verification forward; returns a lazy handle."""

    @abstractmethod
    def commit_target(self, committed: Dict[int, List[int]]
                      ) -> Dict[int, np.ndarray]:
        """Extend the target's slot caches with the accepted tokens;
        returns each request's post-commit tail logits."""

    def commit_target_async(self, committed: Dict[int, List[int]]) -> Future:
        """Non-blocking commit variant for wall-clock executors; the
        future resolves to the tail logits. Default: synchronous."""
        fut: Future = Future()
        fut.set_result(self.commit_target(committed))
        return fut

    # ------------------------------------------------------ drafter ops
    @abstractmethod
    def prefill_drafters(self, reqs: Dict[int, Sequence[int]],
                         batched: bool = False) -> Dict[int, List[float]]:
        """One-behind drafter prefill (context WITHOUT its last token);
        returns {rid: per-drafter mean logprobs} (the routing prior)."""

    @abstractmethod
    def draft_snapshot(self, di: int, rids: Sequence[int]):
        """Speculative slot snapshot for drafter `di` (discard = rollback)."""

    @abstractmethod
    def draft_extend(self, di: int, snap, tokens: np.ndarray):
        """Teacher-force `tokens` (B, T) into a snapshot (optimistic
        draft-ahead warm-up); returns the advanced snapshot."""

    @abstractmethod
    def draft_decode(self, di: int, rids: Sequence[int],
                     tokens: np.ndarray, snap):
        """One drafting step on a snapshot; returns (logits, snapshot)."""

    @abstractmethod
    def commit_drafters(self, committed: Dict[int, List[int]]) -> None:
        """Extend every drafter's slot caches (one-behind commit)."""

    # -------------------------------------------------------- eviction
    @abstractmethod
    def drop_request(self, rid: int) -> None:
        """Release the request's slots on the target and every drafter
        (completion, shed, or preemption). No-op for unknown rids."""

    def shutdown(self) -> None:
        """Release backend resources (worker threads)."""


class SimulatedBackend(ExecutionBackend):
    """Seed semantics: synchronous host execution in engine call order,
    simulated time. Pure mechanical indirection over the runners — the
    byte-identity contract (DESIGN.md §2.7) holds because each method is
    exactly the call the pre-split engine made, in the same order."""

    def now_ms(self) -> float:
        """Simulated engine clock (ms)."""
        return self._engine.clock_ms if self._engine is not None else 0.0

    def prefill_target(self, reqs, batched=False):
        """Prefill the target for {rid: ctx}, optionally as one burst."""
        if batched and len(reqs) > 1:
            return self.target.prefill_requests(reqs)
        return {rid: self.target.prefill_request(rid, ctx)
                for rid, ctx in reqs.items()}

    def prefill_drafters(self, reqs, batched=False):
        """Prefill every drafter; returns {rid: [mean logprob per drafter]}."""
        out: Dict[int, List[float]] = {rid: [] for rid in reqs}
        if batched and len(reqs) > 1:
            for d in self.drafters:
                res = d.prefill_requests(reqs)
                for rid in reqs:
                    out[rid].append(res[rid][1])
            return out
        for rid, ctx in reqs.items():
            for d in self.drafters:
                _, ll = d.prefill_request(rid, ctx)
                out[rid].append(ll)
        return out

    def verify_dispatch(self, rids, tokens, rel_pos, seg_mask):
        """Run tree verification synchronously; handle is pre-resolved."""
        return VerifyHandle(
            value=self.target.verify(rids, tokens, rel_pos, seg_mask))

    def commit_target(self, committed):
        """Commit accepted tokens into the target cache; returns tails."""
        return self.target.extend_committed(committed)

    def commit_drafters(self, committed):
        """Commit accepted tokens into every drafter cache."""
        for d in self.drafters:
            d.extend_committed(committed)

    def draft_snapshot(self, di, rids):
        """Rollback-safe speculative cache copy from drafter `di`."""
        return self.drafters[di].speculative_caches(rids)

    def draft_extend(self, di, snap, tokens):
        """Teacher-force `tokens` into a drafter snapshot."""
        return self.drafters[di].extend_snapshot(snap, tokens)[1]

    def draft_decode(self, di, rids, tokens, snap):
        """One greedy decode step on a drafter snapshot."""
        return self.drafters[di].decode(rids, tokens, caches=snap)

    def drop_request(self, rid):
        """Evict `rid` from the target and every drafter cache."""
        self.target.drop(rid)
        for d in self.drafters:
            d.drop(rid)


class AsyncJaxBackend(ExecutionBackend):
    """Wall-clock backend: a single-worker verification server thread
    owns every target-model operation (totally ordered, so slot-cache
    donation and slot bookkeeping are race-free), drafters run on the
    engine thread, and verify forwards are genuinely in flight while
    the engine drafts ahead.

    `timeline` records each target task's measured wall span
    ({kind, t0, t1}, appended by the worker) — the executor drains it to
    attribute busy/idle time and emit wall-clock spans through the same
    §2.6 trace schema the simulated clocks use."""

    is_wallclock = True

    def __init__(self, target, drafter_specs, max_len: int,
                 paged: bool = False, page_size: int = 64,
                 pool_pages: int = 0):
        super().__init__(target, drafter_specs, max_len,
                         paged=paged, page_size=page_size,
                         pool_pages=pool_pages)
        self._t0 = time.monotonic()
        self._pool = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="verify-server")
        self.timeline: List[dict] = []
        self._timeline_pos = 0

    def now_ms(self) -> float:
        """Wall-clock ms since backend construction."""
        return (time.monotonic() - self._t0) * 1e3

    # ---------------------------------------------------- target worker
    def submit_target(self, kind: str, fn: Callable) -> Tuple[Future, dict]:
        """Queue `fn` on the verification server thread; returns (future,
        span) where span's t0/t1 are filled in by the worker."""
        span = {"kind": kind, "t0": 0.0, "t1": 0.0}

        def _task():
            span["t0"] = self.now_ms()
            try:
                return fn()
            finally:
                span["t1"] = self.now_ms()
                self.timeline.append(span)

        return self._pool.submit(_task), span

    def drain_timeline(self) -> List[dict]:
        """Completed target-task spans since the last drain (the list is
        append-only from the single worker, so reading a prefix is safe)."""
        end = len(self.timeline)
        out = self.timeline[self._timeline_pos:end]
        self._timeline_pos = end
        return out

    # ----------------------------------------------------- target ops
    def prefill_target(self, reqs, batched=True):
        """Blocking burst prefill (see `prefill_target_async`)."""
        return self.prefill_target_async(reqs).result()

    def prefill_target_async(self, reqs) -> Future:
        """Non-blocking burst prefill: queued on the verification server
        (FIFO — it lands before any later-dispatched verify that needs
        it). The future resolves to {rid: (logits, mean logprob)}."""
        reqs = dict(reqs)
        fut, _ = self.submit_target(
            "prefill", lambda: self.target.prefill_requests(reqs))
        return fut

    def verify_dispatch(self, rids, tokens, rel_pos, seg_mask):
        """Queue tree verification on the server thread; lazy handle."""
        B = len(rids)
        vocab = self.target.cfg.vocab

        def _fwd():
            lg = self.target.verify_device(rids, tokens, rel_pos, seg_mask)
            lg.block_until_ready()   # compute timed here; transfer deferred
            return lg

        fut, span = self.submit_target("verify", _fwd)
        return VerifyHandle(
            future=fut, span=span,
            convert=lambda lg: np.asarray(lg[:B, :, :vocab]))

    def commit_target(self, committed):
        """Blocking cache commit (see `commit_target_async`)."""
        return self.commit_target_async(committed).result()

    def commit_target_async(self, committed) -> Future:
        """Non-blocking cache commit: the slot-extend forward (a
        verify-sized target dispatch) is queued on the verification
        server and overlaps the drafter commit + next draft on the
        engine thread. FIFO order guarantees it executes before the
        next verification reads the extended slots; the future resolves
        to the post-commit tail logits, which the engine only consumes
        at the *next* acceptance walk (`_resolve_tails`)."""
        committed = dict(committed)
        fut, _ = self.submit_target(
            "commit", lambda: self.target.extend_committed(committed))
        return fut

    def drop_request(self, rid):
        """Evict `rid`; the target-side release is queued FIFO."""
        # target slot release must serialize behind any queued prefill
        # that may still admit this rid (shed-after-queued-prefill)
        self.submit_target("drop", lambda: self.target.drop(rid))
        for d in self.drafters:
            d.drop(rid)

    # ---------------------------------------------------- drafter ops
    def prefill_drafters(self, reqs, batched=True):
        """Prefill every drafter on the engine thread (drafters are
        engine-thread-owned; only target ops route to the server)."""
        out: Dict[int, List[float]] = {rid: [] for rid in reqs}
        for d in self.drafters:
            res = d.prefill_requests(reqs) if (batched and len(reqs) > 1) \
                else {rid: d.prefill_request(rid, ctx)
                      for rid, ctx in reqs.items()}
            for rid in reqs:
                out[rid].append(res[rid][1])
        return out

    def draft_snapshot(self, di, rids):
        """Rollback-safe speculative cache copy from drafter `di`."""
        return self.drafters[di].speculative_caches(rids)

    def draft_extend(self, di, snap, tokens):
        """Teacher-force `tokens` into a drafter snapshot."""
        return self.drafters[di].extend_snapshot(snap, tokens)[1]

    def draft_decode(self, di, rids, tokens, snap):
        """One greedy decode step on a drafter snapshot."""
        return self.drafters[di].decode(rids, tokens, caches=snap)

    def commit_drafters(self, committed):
        """Commit accepted tokens into every drafter cache."""
        for d in self.drafters:
            d.extend_committed(committed)

    def shutdown(self):
        """Drain and join the verification server thread."""
        self._pool.shutdown(wait=True)


def make_backend(spec, target, drafter_specs, max_len: int,
                 paged: bool = False, page_size: int = 64,
                 pool_pages: int = 0) -> ExecutionBackend:
    """Resolve a backend spec: None/"sim" -> SimulatedBackend, "async" ->
    AsyncJaxBackend, or a ready ExecutionBackend instance. `paged` (from
    `CoSineConfig.paged_pool`) selects the paged KV pool in every runner
    the backend constructs (DESIGN.md §2.8)."""
    if isinstance(spec, ExecutionBackend):
        return spec
    kw = dict(paged=paged, page_size=page_size, pool_pages=pool_pages)
    if spec in (None, "sim"):
        return SimulatedBackend(target, drafter_specs, max_len, **kw)
    if spec == "async":
        return AsyncJaxBackend(target, drafter_specs, max_len, **kw)
    raise ValueError(f"unknown backend {spec!r} (expected 'sim' or 'async')")
