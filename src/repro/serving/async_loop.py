"""Wall-clock serving loop for the `AsyncJaxBackend` (DESIGN.md §2.7).

`WallClockExecutor` is the measured twin of `pipeline.PipelineExecutor`:
the same policy sequence (admission → cohort plan → optimistic
draft-ahead → verify → reconcile), but every duration is *measured wall
time* instead of a discrete-event schedule, and the overlap is physical
rather than booked:

  * the verification forward for cohort k is dispatched to the
    backend's verification-server thread and left **in flight** while
    the engine thread drafts cohort k+1 (the GIL is released inside
    XLA, so drafter forwards and the target forward genuinely share the
    machine);
  * cold requests' prompt forwards are queued on the same server
    (`prefill_target_async`) — FIFO order guarantees the slots exist
    before the first verification that reads them — and their logits
    are resolved lazily right before the acceptance walk;
  * `device_get` of the verification logits is deferred to
    `VerifyHandle.result()`, i.e. the host transfer happens after the
    draft-ahead work has been dispatched.

  * the target-cache commit (`commit_target_async`, itself a
    verify-sized forward) is queued on the server right after the
    acceptance walk and overlaps the drafter commit + next draft on the
    engine thread; its tail logits resolve lazily at the next walk.

Accounting: the backend's `timeline` records each target task's
measured span. The verifier's bubble for a cohort is the wall gap
since the server last finished a verification, minus every task it
executed in between (prefill writes, commit extends) and minus arrival
lulls (an empty pool is not a stall). The same rule applies to the
serial and the overlapped loop, so the serial path's drafting — and
both paths' host-side walk — count as verifier idle. These feed the
same `IterationRecord` fields the simulated executors fill, so
`ServeStats`, the §2.6 trace schema and `benchmarks/wallclock.py`'s
predicted-vs-measured comparison all work unchanged.

Losslessness is inherited: the token-level math is identical to the
simulated path (same `_draft_entries` / `_verify_commit`), so greedy
tree acceptance + correction always commits the target's greedy
continuation — tested in tests/test_backend.py against the AR
reference, including under admission churn.
"""
from __future__ import annotations

import time
from concurrent.futures import Future
from typing import Dict, List, Optional

import numpy as np

from repro.core.scheduler import PipelineObservation
from repro.obs.trace import STAGE
from repro.serving.events import DRAFT, VERIFY
from repro.serving.pipeline import DraftJob


class WallClockExecutor:
    """One measured verification commit per `step()`. With
    `overlap=True` (pipeinfer/cosine) the next cohort is drafted while
    the current verification is in flight on the backend's worker
    thread; with `overlap=False` (vanilla/specinfer) draft and verify
    alternate — the serial coupled baseline, measured."""

    def __init__(self, engine, overlap: bool = True):
        self.eng = engine
        self.tracer = engine.tracer
        self.overlap = overlap
        self.next_job: Optional[DraftJob] = None
        self.busy_ema = 1.0
        self.conf_ema = 1.0
        self.n_survived = 0
        self.n_invalidated = 0
        # rid -> in-flight burst-prefill future (shared per burst); the
        # logits land in eng.entry_logits at _resolve_prefills time
        self._pending_prefill: Dict[int, Future] = {}
        # wall instant the verification server last finished a verify
        self._vfree = 0.0
        # arrival-lull sleep windows [(t0, t1)]: excluded from bubble
        # accounting (an empty pool is not a pipeline stall)
        self._sleeps: List[tuple] = []
        # measured cumulative busy time per stage (observation fracs)
        self._verify_busy_ms = 0.0
        self._draft_busy_ms = 0.0

    # --------------------------------------------------------------- state
    def note_dropped(self, rid: int) -> None:
        """Shed/preempt: a queued burst prefill may still admit this
        rid's slot, but the backend drop is already queued *behind* it,
        and the stale logits must never be consumed (the context could
        be re-prefilled after re-admission)."""
        self._pending_prefill.pop(rid, None)

    def observation(self, backlog: int = 0,
                    waiting: Optional[DraftJob] = None) -> PipelineObservation:
        """Measured wall occupancy since serving start. `waiting` counts
        as queue depth only if it reached the server before the server
        freed from the previous verification — same semantics as the
        simulated pipeline, against the measured `_vfree`."""
        eng = self.eng
        now = max(eng.backend.now_ms(), 1e-9)
        n = len(eng.drafters)
        dfrac = min(self._draft_busy_ms / now, 1.0)
        queued = 1 if (waiting is not None
                       and waiting.ready_ms < self._vfree) else 0
        obs = PipelineObservation(
            verify_busy_frac=min(self._verify_busy_ms / now, 1.0),
            draft_busy_frac=dfrac,
            queue_depth=queued,
            backlog=backlog,
            # no per-node wall clocks: the cluster drafts as one host
            # process, so every node reports the aggregate
            drafter_busy_fracs=[dfrac] * n,
            drafter_wait_fracs=[0.0] * n,
            spec_saturated=eng.sched.spec_saturated)
        m = eng.metrics
        m.set_gauge("pipeline.verify_busy_frac", obs.verify_busy_frac)
        m.set_gauge("pipeline.draft_busy_frac", obs.draft_busy_frac)
        m.set_gauge("pipeline.queue_depth", obs.queue_depth)
        m.set_gauge("pipeline.backlog", obs.backlog)
        for i, f in enumerate(obs.drafter_busy_fracs):
            m.set_gauge("draft.node_busy_frac", f, node=i)
        return obs

    def _observe_conf(self, entries) -> None:
        conf = float(np.mean(np.concatenate([e.fused_p for e in entries])))
        self.conf_ema = 0.7 * self.conf_ema + 0.3 * conf

    # ------------------------------------------------------------ prefill
    def _gc_prefills(self, live_rids) -> None:
        for rid in list(self._pending_prefill):
            if rid not in live_rids:
                self._pending_prefill.pop(rid, None)

    def _resolve_prefills(self, entries) -> None:
        """Land the burst-prefill logits for this cohort's cold members
        before the acceptance walk consumes them. The prefill was queued
        before this cohort's verification, so the wait (if any) ends
        strictly before the verification does."""
        eng = self.eng
        for e in entries:
            fut = self._pending_prefill.pop(e.req.rid, None)
            if fut is not None:
                eng.entry_logits[e.req.rid] = fut.result()[e.req.rid][0]

    # ------------------------------------------------------------ drafting
    def _spawn(self, prev: Optional[DraftJob]) -> Optional[DraftJob]:
        """Draft the next cohort on the engine thread (concurrent with
        `prev`'s verification in flight on the worker). Cold requests'
        target prefills are queued asynchronously; drafter prefills run
        here (the drafters' next decode needs them immediately)."""
        eng = self.eng
        inflight = ({e.req.rid: e for e in prev.entries} if prev else {})
        t_now = eng.backend.now_ms()

        def avail(r):
            if r.rid in inflight:
                return r.arrival_ms
            return eng.avail_ms.get(r.rid, r.arrival_ms)

        everyone = eng.pool.pending(float("inf"))
        self._gc_prefills({r.rid for r in everyone})
        cands = [r for r in everyone if avail(r) <= t_now]
        if not cands and prev is None:
            if not everyone:
                return None
            # real arrival lull: sleep the wall clock to the next arrival
            t_next = min(avail(r) for r in everyone)
            if t_next > t_now:
                time.sleep((t_next - t_now) / 1e3)
                self._sleeps.append((t_now, eng.backend.now_ms()))
            t_now = max(eng.backend.now_ms(), t_next)
            cands = [r for r in everyone if avail(r) <= t_now]

        def opt_ext(r):
            e = inflight.get(r.rid)
            return (e.gamma + 1) if e is not None else 0

        cands = [r for r in cands
                 if r.rid not in inflight
                 or r.max_new_tokens - len(r.generated) - opt_ext(r) > 0]
        if not cands:
            return None
        obs = self.observation(backlog=len(cands), waiting=prev)
        if eng.admission is not None:
            cands = eng._apply_admission(
                cands, t_now, obs, inflight_rids=frozenset(inflight),
                pipe_empty=prev is None)
            if not cands:
                return None
            obs = self.observation(backlog=len(cands), waiting=prev)
        cohort = eng._next_cohort()

        cold = [r for r in cands if r.rid not in eng.entry_logits
                and r.rid not in self._pending_prefill]
        if cold:
            for r in cold:
                if r.n_preemptions > 0 and r.generated:
                    eng.tracer.mark("readmit", r.rid, t_now)
            ctxs = {r.rid: list(r.prompt) + r.generated for r in cold}
            # one masked slot_extend on the verification server, in
            # flight while we prefill the drafters and draft below
            fut = eng.backend.prefill_target_async(ctxs)
            for r in cold:
                self._pending_prefill[r.rid] = fut
            lls = eng.backend.prefill_drafters(
                {rid: c[:-1] for rid, c in ctxs.items()})
            if eng.strategy == "cosine" and eng.cfg.enable_routing:
                for rid in ctxs:
                    eng.router.set_prior(rid, lls[rid])

        extra = {r.rid: opt_ext(r) for r in cands if r.rid in inflight}
        batch, gammas = eng._plan_cohort(cands, observation=obs,
                                         extra_ctx=extra, now_ms=t_now)
        optim = {r.rid: inflight[r.rid].d_chains
                 for r in batch if r.rid in inflight}
        parts = [eng._participants(r) for r in batch]
        rids = tuple(r.rid for r in batch)
        t0 = eng.backend.now_ms()
        entries = eng._draft_entries(batch, gammas, optimistic=optim,
                                     parts=parts)
        for e in entries:
            if e.req.rid in optim:
                e.assumed = [int(t) for t in inflight[e.req.rid].fused_t]
        self._observe_conf(entries)
        t1 = eng.backend.now_ms()
        self._draft_busy_ms += t1 - t0
        self.tracer.span("draft", STAGE, DRAFT, t0, t1, cohort=cohort,
                         rids=rids)
        return DraftJob(entries, t0, t1 - t0, t1,
                        eng.n_active(entries), cohort=cohort)

    # ------------------------------------------------------------ reconcile
    def _reconcile(self, ahead: DraftJob, committed: Dict[int, List[int]],
                   t_known_ms: float) -> Optional[DraftJob]:
        """pipeline.PipelineExecutor._reconcile, measured: survivors
        shift, invalidated requests redraft on the engine thread and the
        redraft's wall time extends the job."""
        eng = self.eng
        keep, redo, invalid = [], [], []
        for e in ahead.entries:
            if e.req.done:
                continue
            if e.assumed is None:
                keep.append(e)
                continue
            toks = committed.get(e.req.rid)
            survives = (toks is not None
                        and len(toks) == len(e.assumed) + 1
                        and toks[:-1] == e.assumed
                        and toks[-1] == int(e.fused_t[0]))
            if survives:
                self.n_survived += 1
                eng.metrics.inc("pipeline.survived")
                shifted = eng._shift_entry(e)
                if shifted is not None:
                    shifted.assumed = None
                    keep.append(shifted)
                else:
                    redo.append(e.req)
            else:
                invalid.append(e.req)
                redo.append(e.req)
        self.n_invalidated += len(invalid)
        ahead.entries = keep
        if invalid:
            eng.metrics.inc("pipeline.invalidated", len(invalid))
            for r in invalid:
                self.tracer.mark("invalidate", r.rid, t_known_ms,
                                 cohort=ahead.cohort)
        if redo:
            gammas = eng._cohort_gammas(redo)
            parts = [eng._participants(r) for r in redo]
            t0 = eng.backend.now_ms()
            redo_entries = eng._draft_entries(redo, gammas, parts=parts)
            self._observe_conf(redo_entries)
            t1 = eng.backend.now_ms()
            self._draft_busy_ms += t1 - t0
            self.tracer.span("redraft", STAGE, DRAFT, t0, t1,
                             cohort=ahead.cohort,
                             rids=tuple(r.rid for r in redo))
            ahead.entries = keep + redo_entries
            ahead.draft_ms += t1 - t0
            ahead.ready_ms = max(ahead.ready_ms, t1)
            ahead.n_active = max(ahead.n_active, eng.n_active(redo_entries))
        if not ahead.entries:
            return None
        return ahead

    # ------------------------------------------------------------ one step
    def step(self):
        """One wall-clock serving iteration: draft (or reuse the
        draft-ahead job), dispatch verification, walk acceptance,
        commit, and spawn the next draft-ahead job."""
        eng = self.eng
        job, self.next_job = self.next_job, None
        if job is None:
            job = self._spawn(None)
            if job is None:
                return None

        batch = [e.req for e in job.entries]
        big_gamma = sum(e.tree.n_nodes for e in job.entries)
        # verification in flight on the worker from here on
        handle = eng._verify_dispatch(job.entries)
        # draft-ahead on this thread, physically concurrent with it
        ahead = self._spawn(job) if self.overlap else None
        self._resolve_prefills(job.entries)
        committed, total_committed = eng._verify_commit(job.entries,
                                                        handle=handle)
        vstart, vend = handle.times()
        t_llm = vend - vstart

        # measured server-side accounting: the verify server's idle for
        # this cohort is the wall gap since it last finished a verify,
        # minus every task it executed in between (prefill writes,
        # async commit extends) and minus arrival lulls (an empty pool
        # is not a pipeline stall). One uniform rule for the serial and
        # the overlapped loop — what the serial path spends drafting
        # (and both paths spend walking/committing on the host) is
        # honestly counted as verifier idle.
        spans = eng.backend.drain_timeline()
        floor = self._vfree if self._vfree > 0.0 else job.draft_start_ms
        other_busy = sum(
            min(s["t1"], vstart) - max(s["t0"], floor)
            for s in spans
            if s["kind"] != "verify"
            and s["t1"] > floor and s["t0"] < vstart)
        lull = sum(min(t1, vstart) - max(t0, floor)
                   for t0, t1 in self._sleeps
                   if t1 > floor and t0 < vstart)
        self._sleeps = [s for s in self._sleeps if s[1] > vstart]
        prefill_ms = sum(s["t1"] - s["t0"] for s in spans
                         if s["kind"] == "prefill")
        bubble = max(0.0, vstart - floor - other_busy - lull)
        self._verify_busy_ms += sum(s["t1"] - s["t0"] for s in spans)
        self.tracer.span("verify", STAGE, VERIFY, vstart, vend,
                         cohort=job.cohort,
                         rids=tuple(r.rid for r in batch))
        if bubble > 0:
            self.tracer.span("bubble", STAGE, VERIFY, vstart - bubble,
                             vstart, cohort=job.cohort,
                             rids=tuple(r.rid for r in batch),
                             cause="await_draft")
        for s in spans:
            if s["kind"] == "prefill":
                self.tracer.span("prefill", STAGE, VERIFY, s["t0"],
                                 s["t1"], cohort=job.cohort)

        wait = max(self._vfree - job.ready_ms, 0.0)
        busy_obs = (t_llm + wait) / max(t_llm + bubble, 1e-9)
        self.busy_ema = 0.6 * self.busy_ema + 0.4 * busy_obs
        self._vfree = vend

        queue_depth = 1 if (ahead is not None and ahead.ready_ms <= vend) \
            else 0
        from repro.serving.engine import IterationRecord
        t_start = max(eng.clock_ms, job.draft_start_ms)
        rec = IterationRecord(
            t_start_ms=t_start, t_iter_ms=vend - t_start,
            batch=len(batch), big_gamma=big_gamma,
            committed=total_committed, n_active_drafters=job.n_active,
            cohort=job.cohort,
            draft_start_ms=job.draft_start_ms, draft_ms=job.draft_ms,
            verify_start_ms=vstart, verify_ms=t_llm,
            verify_idle_ms=bubble, prefill_ms=prefill_ms,
            queue_depth=queue_depth)
        eng._finalize(batch, committed, rec)

        if eng.strategy == "cosine":
            for e in job.entries:
                if not e.req.done:
                    eng.sched.update_gamma_feedback(
                        e.req, len(committed[e.req.rid]), self.busy_ema,
                        now_ms=vend)

        if ahead is not None:
            n_inv0 = self.n_invalidated
            ahead = self._reconcile(ahead, committed, vend)
            rec.n_invalidated = self.n_invalidated - n_inv0
        self.next_job = ahead
        return rec
