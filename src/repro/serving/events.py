"""Discrete-event primitives for the decoupled serving pipeline
(DESIGN.md §2).

The executor models the paper's deployment as two serial resources — the
speculation cluster ("draft") and the verification server ("verify") —
each advancing its own simulated clock. `StageClock` is the scheduling
primitive: work is placed on a stage no earlier than its release time,
and the gap between the stage becoming free and the work starting is
*measured idle time* (a pipeline bubble), not an analytic formula.

Every state transition is appended to an `EventLog` with a global
sequence number, so the interleaving of the two stages is a
deterministic, inspectable trace: two runs of the same engine with the
same seed must produce byte-identical event streams (tested in
tests/test_pipeline.py). For long runs the log can be ring-bounded
(`max_events`): the oldest events drop and `n_dropped` counts them (the
cap unhit, determinism tests see the identical full stream).

When a `Tracer` (obs/trace.py) is attached, every scheduled job also
emits an occupancy span on the stage's track — and every measured idle
gap an explicit ``bubble`` span carrying its cause — so the exported
trace's per-stage busy/idle totals equal this clock's accounting exactly
(DESIGN.md §2.6).
"""
from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass
from typing import Deque, Optional, Tuple

from repro.obs.trace import STAGE, Tracer

DRAFT = "draft"
VERIFY = "verify"


@dataclass(frozen=True)
class Event:
    """One pipeline state transition at simulated time `t_ms`.

    `seq` is a global monotone counter: events with equal timestamps have
    a deterministic total order (host execution order), which makes the
    trace reproducible and diffable across runs.
    """
    t_ms: float
    seq: int
    stage: str                      # DRAFT | VERIFY
    kind: str                       # "start" | "end" | "invalidate" | ...
    rids: Tuple[int, ...] = ()
    info: str = ""

    def key(self):
        """Identity used by the determinism tests (everything observable)."""
        return (round(self.t_ms, 6), self.seq, self.stage, self.kind,
                self.rids, self.info)


class EventLog:
    """Bounded, totally-ordered serving event log (the `seq` counter
    breaks ties at equal simulated times — DESIGN.md §2.2)."""

    def __init__(self, max_events: int = 0):
        self.max_events = int(max_events)
        self.events: Deque[Event] = deque(
            maxlen=self.max_events if self.max_events > 0 else None)
        self._seq = itertools.count()
        self.n_dropped = 0

    def emit(self, t_ms: float, stage: str, kind: str,
             rids: Tuple[int, ...] = (), info: str = "") -> Event:
        """Append one event (drops the oldest past `max_events`)."""
        if self.max_events > 0 and len(self.events) == self.max_events:
            self.n_dropped += 1
        ev = Event(float(t_ms), next(self._seq), stage, kind,
                   tuple(int(r) for r in rids), info)
        self.events.append(ev)
        return ev

    def trace(self):
        """Deterministic comparison key list for the retained events."""
        return [ev.key() for ev in self.events]


@dataclass
class StageClock:
    """A serial pipeline stage with busy/idle accounting.

    `free_ms` is the time at which the stage can next begin work.
    `schedule()` places one unit of work: it starts at
    max(free_ms, not_before_ms); any gap is recorded as idle (bubble)
    time. Busy/idle fractions here are *measured from the event
    timeline*, which is what the adaptive speculation feedback loop
    consumes (Alg. 2) instead of the old analytic busy ratio.
    """
    name: str
    log: Optional[EventLog] = None
    tracer: Optional[Tracer] = None
    free_ms: float = 0.0
    busy_ms: float = 0.0
    idle_ms: float = 0.0
    n_jobs: int = 0
    # queue accounting: time jobs spent waiting because this stage was
    # still busy (their release time was earlier than free_ms) and how
    # many jobs waited at all — per-node queue occupancy for the cluster
    wait_ms: float = 0.0
    n_queued: int = 0

    def park(self, t_ms: float):
        """Advance the stage to `t_ms` without accruing idle time: the
        stage had no work *available* (e.g. an arrival lull), which is
        not a pipeline bubble. Never moves the clock backwards."""
        if t_ms > self.free_ms:
            self.free_ms = t_ms

    def schedule(self, duration_ms: float, not_before_ms: float = 0.0,
                 kind: str = "work", rids: Tuple[int, ...] = (),
                 release_ms: Optional[float] = None,
                 cohort: int = -1, cause: Optional[str] = None):
        """Run `duration_ms` of work; returns (start, end, idle_gap).

        release_ms: when the job actually became runnable, for the queue
        accounting only (defaults to not_before_ms). A job released
        while the stage was still busy counts the gap as queue wait.
        cohort/cause: trace attribution — the cohort the job belongs to,
        and what an idle gap ahead of it was waiting for (defaults to
        the job's own kind)."""
        start = max(self.free_ms, not_before_ms)
        gap = start - self.free_ms
        end = start + duration_ms
        self.idle_ms += gap
        self.busy_ms += duration_ms
        self.n_jobs += 1
        release = not_before_ms if release_ms is None else release_ms
        waited = max(self.free_ms - release, 0.0)
        if waited > 0.0:
            self.wait_ms += waited
            self.n_queued += 1
        free_before = self.free_ms
        self.free_ms = end
        if self.log is not None:
            self.log.emit(start, self.name, f"{kind}_start", rids)
            self.log.emit(end, self.name, f"{kind}_end", rids)
        if self.tracer is not None:
            if gap > 0.0:
                self.tracer.span("bubble", STAGE, self.name, free_before,
                                 start, cohort=cohort, rids=rids,
                                 cause=cause or kind)
            self.tracer.span(kind, STAGE, self.name, start, end,
                             cohort=cohort, rids=rids)
        return start, end, gap

    def busy_frac(self) -> float:
        """Measured occupancy over the stage's active span. A stage that
        was never scheduled reads 0.0 — it is idle capacity, not
        saturation (a no-evidence default of 1.0 made never-used drafter
        nodes look saturated to `plan()`'s drafter-feedback trim)."""
        span = self.busy_ms + self.idle_ms
        return self.busy_ms / span if span > 0 else 0.0
