"""ModelRunner: executes one model (target LLM or drafter SSM) over a
slot-based, device-resident batched cache with jit-compiled,
shape-bucketed step functions.

Slot model (continuous batching): the runner preallocates ONE cache
pytree whose batch axis is a pool of request *slots*. Requests are
admitted into free slots at prefill and evicted on completion; every
batched step passes its active slot indices into the model's write path
(`model.slot_decode_step` / `slot_verify_chunk` / `slot_extend` →
`apply(..., slot_idx=...)`), which scatters only the new tokens' rows
into the resident cache in place (paged-attention style) and gathers
only the active rows for attention/SSM reads — per-step cache byte
traffic scales with the number of new tokens, not bucket x capacity,
and no host-side pytree reassembly (`stack_caches`/`split_cache`)
happens per step. Active-slot counts are padded to buckets to bound
recompiles; padded rows are mapped to a dedicated scratch slot (index 0)
that no request ever owns, so their garbage writes are never read.

Speculative rollback is snapshot-based: drafting gathers a compact
sub-cache once (`speculative_caches`, a device-side copy) and decodes on
it without ever scattering back — discarding the snapshot IS the
rollback (correct for both attention KV and SSM recurrent state).

Paged mode (`ModelRunner(..., paged=True)`, DESIGN.md §2.8): the
attention/MLA KV lives in a fixed page pool instead of reserved
per-slot rows. `PagedSlotCacheManager` keeps a host-side block table
per request and hands every step a `page_view` — admission, eviction
and rollback become block-table operations, memory scales with tokens
actually held, and long prompts are not bounded by `max_len`.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import model as M
from repro.models import quantize

# ---------------------------------------------------------------------
# Shape-bucket constants — the single source of truth (tests import
# these; do not duplicate the values elsewhere).
#
# Rationale: every distinct (batch rows, token width) pair that reaches
# a jitted step function costs one XLA compile. Both axes are therefore
# snapped to power-of-two buckets: compiles are O(log) in the largest
# shape seen, and the pad rows/columns are masked out (scratch slot /
# token_mask) so bucketing never changes results.
#
# PREFILL_BUCKETS / PREFILL_CHUNK (token-width axis): an arbitrary-length
# prompt streams through `slot_extend` as full PREFILL_CHUNK-sized
# writes plus ONE final chunk padded up to the next bucket with the pad
# masked out (token_mask), so a 7-token prompt is a single masked 8-wide
# write instead of a 4+2+1 bucket decomposition — compile shapes stay
# bounded and the number of forwards is ceil(P / PREFILL_CHUNK).
# Sliding-window configs chunk at RING_MARGIN instead — see
# `prefill_chunk_len` for why a scatter may not span more ring columns.
#
# SLOT_BUCKETS (batch-rows axis): active-batch sizes are snapped up via
# `slot_bucket`; the enumeration just bounds the table — past its last
# entry the clamp continues with the next power of two (one compile per
# doubling, never one per batch size).
PREFILL_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512)
PREFILL_CHUNK = 512
SLOT_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256)

# Speculative snapshots gathered from a paged pool reserve this much
# column slack past each request's length so draft-ahead writes (gamma
# plus assumed-extension chains) never wrap a full-attention snapshot.
# RING_MARGIN-sized for the same reason the ring margin exists: it is
# the largest segment one step may write.
SNAP_SLACK = 128


def prefill_bucket(n: int) -> int:
    """Smallest prefill chunk shape >= n (n <= PREFILL_CHUNK)."""
    for b in PREFILL_BUCKETS:
        if b >= n:
            return b
    return PREFILL_CHUNK


def prefill_chunk_len(cfg: ModelConfig) -> int:
    """Max prefill chunk width for a config. Sliding-window layers cache
    KV in a ring of capacity window + RING_MARGIN; one scatter may only
    span RING_MARGIN positions (real + pad) or its columns wrap onto
    keys still inside some query's window — so windowed configs chunk at
    the margin, full-attention ones at PREFILL_CHUNK."""
    from repro.models.attention import RING_MARGIN
    from repro.models.model import effective_window
    win = effective_window(cfg)
    return min(PREFILL_CHUNK, RING_MARGIN) if win else PREFILL_CHUNK


def slot_bucket(n: int) -> int:
    """Smallest bucket >= n (bounds the number of compiled batch shapes).
    Past the enumerated buckets, clamp to the next power of two — one
    compile per doubling, never one per active-batch size."""
    for b in SLOT_BUCKETS:
        if b >= n:
            return b
    return 1 << (n - 1).bit_length()


# Module-level jitted steps with cfg static: every ModelRunner with the
# same (hashable, frozen) ModelConfig shares one compile cache — engines
# are created freely in benchmarks without re-tracing. The slotted cache
# is donated where it is replaced, so XLA updates it in place.
_g_decode = jax.jit(M.decode_step, static_argnames=("cfg",))
_g_extend_plain = jax.jit(M.extend, static_argnames=("cfg",),
                          donate_argnames=("cache",))
_g_slot_decode = jax.jit(M.slot_decode_step, static_argnames=("cfg",),
                         donate_argnames=("cache",))
_g_slot_extend = jax.jit(M.slot_extend, static_argnames=("cfg",),
                         donate_argnames=("cache",))
_g_slot_verify = jax.jit(M.slot_verify_chunk, static_argnames=("cfg",))
_g_gather = jax.jit(M.gather_slots)
_g_scatter = jax.jit(M.scatter_slots, donate_argnames=("cache",))
_g_gather_paged = jax.jit(M.gather_paged_slots, static_argnames=("cfg",))
_g_reset_slot = jax.jit(M.reset_slot_state, static_argnames=("cfg",),
                        donate_argnames=("cache",))
_g_reset_pages = jax.jit(M.reset_pages, static_argnames=("cfg",),
                         donate_argnames=("cache",))


class SlotCacheManager:
    """Owns the slotted cache: slot admission/eviction/reset and
    capacity growth (doubling — recompiles are O(log max_concurrency)).

    Slot 0 is scratch (padding target); real slots are 1..n_slots.
    """

    SCRATCH = 0

    def __init__(self, cfg: ModelConfig, max_len: int, n_slots: int = 8,
                 dtype=jnp.float32):
        self.cfg = cfg
        self.max_len = max_len
        self.dtype = dtype
        self.n_slots = n_slots
        self.cache = M.init_cache(cfg, n_slots + 1, max_len, dtype=dtype)
        # pristine single-slot cache used to reset a slot on (re)admission:
        # clears stale slot_pos / SSM state left by the previous tenant
        self._empty = M.init_cache(cfg, 1, max_len, dtype=dtype)
        self._free = list(range(n_slots, 0, -1))      # pop() -> slot 1 first
        self.slot_of: Dict[int, int] = {}
        self._idx_cache: Dict[tuple, jnp.ndarray] = {}

    IDX_CACHE_MAX = 512

    # -------------------------------------------------------------- admission
    def admit(self, rid: int) -> int:
        """Assign (or return) `rid`'s slot, growing the pool if full."""
        if rid in self.slot_of:
            return self.slot_of[rid]
        if not self._free:
            self._grow()
        slot = self._free.pop()
        self.slot_of[rid] = slot
        # admission never remaps existing rids, so memoized index arrays
        # for other batches stay valid — streaming arrivals must not evict
        # the hot decode-batch indices
        self.cache = _g_scatter(self.cache, self._empty,
                                jnp.asarray([slot], jnp.int32))
        return slot

    def release(self, rid: int):
        """Free `rid`'s slot and drop stale memoized batch indices."""
        slot = self.slot_of.pop(rid, None)
        if slot is not None:
            self._free.append(slot)
            # only batches that contained the departing rid are stale (its
            # slot may be re-issued to a different request)
            for key in [k for k in self._idx_cache if rid in k]:
                del self._idx_cache[key]

    def _grow(self):
        extra = M.init_cache(self.cfg, self.n_slots, self.max_len,
                             dtype=self.dtype)
        self.cache = M.concat_slots(self.cache, extra)
        self._free.extend(range(2 * self.n_slots, self.n_slots, -1))
        self.n_slots *= 2

    # -------------------------------------------------------------- indexing
    def padded_idx(self, rids: Sequence[int]) -> jnp.ndarray:
        """Bucketed (B_bucket,) slot indices; padding rows -> scratch.

        Memoized per rids tuple (hot decode loops reuse the same batch for
        many steps). Admissions leave the memo intact; evictions drop only
        the entries containing the departing rid; total size is bounded by
        IDX_CACHE_MAX (FIFO eviction of the oldest batches)."""
        key = tuple(rids)
        idx = self._idx_cache.get(key)
        if idx is None:
            while len(self._idx_cache) >= self.IDX_CACHE_MAX:
                self._idx_cache.pop(next(iter(self._idx_cache)))
            lst = [self.slot_of[r] for r in rids]
            lst += [self.SCRATCH] * (slot_bucket(len(lst)) - len(lst))
            idx = self._idx_cache[key] = jnp.asarray(lst, jnp.int32)
        return idx

    def length(self, rid: int) -> int:
        """Committed tokens in `rid`'s slot (device-authoritative)."""
        return int(self.cache["lengths"][self.slot_of[rid]])

    # ------------------------------------------------------------ paged hooks
    # The resident pool reserves full capacity per slot, so the paged
    # protocol (allocate-before-write, block-table views) is a no-op
    # here; ModelRunner calls these unconditionally and passes the
    # returned page_view (None) straight through to the step functions.
    def prepare(self, rids: Sequence[int], write: int,
                read_extra: int = 0) -> Optional[jnp.ndarray]:
        """Allocate pages for the next `write` columns of each rid and
        return the batch page_view (None on the resident pool)."""
        return None

    def advance(self, rid: int, n: int):
        """Advance the host-side length mirror after a committed write
        of `n` real tokens (paged bookkeeping; no-op here)."""

    def snapshot_view(self, rids: Sequence[int]) -> Optional[jnp.ndarray]:
        """Read-only page_view for a speculative snapshot gather (None
        on the resident pool)."""
        return None


class PagedSlotCacheManager(SlotCacheManager):
    """Slot manager over a paged KV pool (DESIGN.md §2.8).

    Attention/MLA KV lives in one fixed pool of `page_size`-token pages
    per sub-layer; each request owns an ordered host-side *block table*
    mapping its logical pages to physical ones. SSM state, cross-attn
    KV and `lengths` stay slot-indexed (they are O(1) per request).

    Protocol: every write site calls `prepare(rids, write=W)` first —
    it allocates any page the next W columns touch and returns the
    bucketed (rows, n_view) page_view — and `advance(rid, n_real)`
    after the write commits. Eviction (`release`) returns the pages to
    the free list and wipes their slot_pos in one batched reset, so
    recycled pages are invisible until rewritten; admission resets only
    the slot-indexed leaves. Rollback needs nothing at all: speculative
    snapshots are gathered *copies* (`gather_paged_slots`), so dropping
    a snapshot can never leak or alias pages.

    Physical pages 0 and 1 are reserved: 0 is SCRATCH (write target for
    padded batch rows — garbage, never read) and 1 is NULL (read filler
    for unmapped view entries — slot_pos stays -1 forever, never
    written, so it masks like any empty slot).

    Windowed (SWA) layers keep their ring semantics: the block table is
    a fixed ring of C/page_size entries (C = window + RING_MARGIN,
    page_size fitted to divide C) allocated on first touch, and the
    view is always the whole ring — write columns pos % C land on the
    same pages as the resident ring, bit-for-bit.
    """

    SCRATCH_PAGE = 0
    NULL_PAGE = 1
    _RESERVED = 2

    def __init__(self, cfg: ModelConfig, max_len: int, n_slots: int = 8,
                 dtype=jnp.float32, page_size: int = 64,
                 pool_pages: int = 0):
        from repro.models.attention import cache_capacity
        self.cfg = cfg
        self.max_len = max_len
        self.dtype = dtype
        self.n_slots = n_slots
        win = M.effective_window(cfg)
        ps = max(1, page_size)
        if win:
            cap = cache_capacity(cfg, max_len, win)
            while cap % ps:        # ring capacity must be whole pages
                ps //= 2
            self.ring_pages = cap // ps
        else:
            self.ring_pages = 0
        self.page_size = ps
        n_pages = pool_pages or (self._RESERVED + 4 * n_slots)
        n_pages = max(n_pages, self._RESERVED + 1)
        self.n_pages = n_pages
        self.cache = M.init_paged_cache(cfg, n_slots + 1, dtype=dtype,
                                        page_size=ps, n_pages=n_pages)
        self._free = list(range(n_slots, 0, -1))      # pop() -> slot 1 first
        self._free_pages = list(range(n_pages - 1, self._RESERVED - 1, -1))
        self.slot_of: Dict[int, int] = {}
        self._idx_cache: Dict[tuple, jnp.ndarray] = {}
        self.tables: Dict[int, List[int]] = {}
        self.host_len: Dict[int, int] = {}

    # -------------------------------------------------------------- admission
    def admit(self, rid: int) -> int:
        """Assign a slot + empty block table; resets only the
        slot-indexed leaves (pages are mapped lazily by `prepare`)."""
        if rid in self.slot_of:
            return self.slot_of[rid]
        if not self._free:
            self._grow()
        slot = self._free.pop()
        self.slot_of[rid] = slot
        self.tables[rid] = [-1] * self.ring_pages if self.ring_pages else []
        self.host_len[rid] = 0
        self.cache = _g_reset_slot(cfg=self.cfg, cache=self.cache,
                                   slot_idx=jnp.asarray([slot], jnp.int32))
        return slot

    def release(self, rid: int):
        """Free the slot, wipe the mapped pages' slot_pos in one
        batched reset, and return them to the free list."""
        pids = [p for p in self.tables.pop(rid, []) if p >= 0]
        self.host_len.pop(rid, None)
        super().release(rid)
        if pids:
            # one batched slot_pos wipe; pad to a power-of-two count with
            # the NULL page (already -1, so the pad is a no-op)
            n = 1 << (len(pids) - 1).bit_length()
            padded = pids + [self.NULL_PAGE] * (n - len(pids))
            self.cache = _g_reset_pages(
                cfg=self.cfg, cache=self.cache,
                page_ids=jnp.asarray(padded, jnp.int32))
            self._free_pages.extend(reversed(pids))

    def _grow(self):
        extra = M.init_paged_cache(self.cfg, self.n_slots, dtype=self.dtype,
                                   page_size=self.page_size, n_pages=2)
        self.cache = M.concat_slots_paged(self.cfg, self.cache, extra)
        self._free.extend(range(2 * self.n_slots, self.n_slots, -1))
        self.n_slots *= 2

    def _grow_pages(self):
        extra = self.n_pages                      # double the pool
        self.cache = M.grow_pages(self.cfg, self.cache, extra)
        self._free_pages = (list(range(self.n_pages + extra - 1,
                                       self.n_pages - 1, -1))
                            + self._free_pages)
        self.n_pages += extra

    def _alloc_page(self) -> int:
        if not self._free_pages:
            self._grow_pages()
        return self._free_pages.pop()

    # -------------------------------------------------------------- paging
    def ensure(self, rid: int, upto: int):
        """Map every page that columns [host_len, upto) touch. Full
        attention grows the table; windowed maps ring entries on first
        touch. Called by `prepare` before any write."""
        tbl = self.tables[rid]
        hl = self.host_len[rid]
        ps = self.page_size
        if upto <= hl:
            return
        if self.ring_pages:
            for lp in range(hl // ps, (upto - 1) // ps + 1):
                r = lp % self.ring_pages
                if tbl[r] < 0:
                    tbl[r] = self._alloc_page()
        else:
            need = (upto + ps - 1) // ps
            while len(tbl) < need:
                tbl.append(self._alloc_page())

    def view(self, rids: Sequence[int], extra: int = 0) -> jnp.ndarray:
        """Bucketed (rows, n_view) block-table view for a batch.

        n_view covers each rid's held tokens plus `extra` columns,
        snapped to a power of two (windowed: always the whole ring).
        Unmapped entries -> NULL page; padded batch rows -> SCRATCH."""
        rows = slot_bucket(max(len(rids), 1))
        ps = self.page_size
        if self.ring_pages:
            nv = self.ring_pages
        else:
            need = 1
            for r in rids:
                need = max(need, -(-(self.host_len[r] + extra) // ps))
            nv = 1 << (need - 1).bit_length()
        out = np.full((rows, nv), self.NULL_PAGE, np.int32)
        for j, r in enumerate(rids):
            for i, p in enumerate(self.tables[r][:nv]):
                if p >= 0:
                    out[j, i] = p
        out[len(rids):, :] = self.SCRATCH_PAGE
        return jnp.asarray(out)

    def prepare(self, rids: Sequence[int], write: int,
                read_extra: int = 0) -> jnp.ndarray:
        """Allocate pages for the next `write` columns of each rid and
        return the page_view covering held + write + read_extra."""
        if write:
            for r in rids:
                self.ensure(r, self.host_len[r] + write)
        return self.view(rids, extra=write + read_extra)

    def advance(self, rid: int, n: int):
        """Record `n` committed tokens (host paging mirror)."""
        self.host_len[rid] += n

    def snapshot_view(self, rids: Sequence[int]) -> jnp.ndarray:
        """View for a snapshot gather with SNAP_SLACK columns of slack so
        draft-ahead writes on the (copied) snapshot never wrap."""
        return self.view(rids, extra=SNAP_SLACK)

    # -------------------------------------------------------------- accounting
    def pages_held(self) -> int:
        """Physical pages currently mapped by live requests."""
        return sum(sum(1 for p in t if p >= 0) for t in self.tables.values())

    def fragmentation(self) -> float:
        """Fraction of held page capacity that is not live tokens —
        internal fragmentation of the tail pages (0.0 = perfectly full)."""
        held = self.pages_held() * self.page_size
        if not held:
            return 0.0
        live = sum(min(self.host_len[r], self.ring_pages * self.page_size
                       if self.ring_pages else self.host_len[r])
                   for r in self.tables)
        return 1.0 - live / held


class ModelRunner:
    """Executes one model over its slot cache with jitted, bucketed steps.

    paged=True swaps the reserved-capacity `SlotCacheManager` for the
    `PagedSlotCacheManager` (page-pool KV, block tables — DESIGN.md
    §2.8); every step then threads the manager's `page_view` into the
    model's read/write path. The two modes produce identical committed
    tokens — the paged path is gated behind `CoSineConfig.paged_pool`.
    """

    def __init__(self, cfg: ModelConfig, params, max_len: int = 512,
                 cache_dtype=jnp.float32, n_slots: int = 8,
                 paged: bool = False, page_size: int = 64,
                 pool_pages: int = 0):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.cache_dtype = cache_dtype
        self.paged = paged
        if paged:
            self.slots: SlotCacheManager = PagedSlotCacheManager(
                cfg, max_len, n_slots, cache_dtype,
                page_size=page_size, pool_pages=pool_pages)
        else:
            self.slots = SlotCacheManager(cfg, max_len, n_slots, cache_dtype)
        # routing prior embeddings: dequantized view for weight-only
        # int8 params (the router works in f32 host space either way)
        self.embed_np = np.asarray(
            quantize.dequantize_weight(params["embed"])[: cfg.vocab],
            np.float32)
        # masked slot_extend writes issued by the prefill paths (the
        # burst-admission test asserts batched prefill issues fewer)
        self.n_prefill_writes = 0

        self._jit_decode = partial(_g_decode, cfg=cfg)
        self._jit_extend_plain = partial(_g_extend_plain, cfg=cfg)
        self._jit_slot_decode = partial(_g_slot_decode, cfg=cfg)
        self._jit_slot_extend = partial(_g_slot_extend, cfg=cfg)
        self._jit_slot_verify = partial(_g_slot_verify, cfg=cfg)
        self._jit_gather_paged = partial(_g_gather_paged, cfg=cfg)

    # ----------------------------------------------------------- lifecycle
    def prefill_request(self, rid: int, tokens: np.ndarray):
        """Admit a slot and prefill the request's context; returns
        (last-position logits (V,), mean next-token logprob of the context
        under this model).

        The logprob is the engine's content-based routing prior (paper §5:
        requests are analyzed and matched to suitable drafters before
        inference). Runs in shape buckets (exact coverage — no padded
        garbage reaches SSM states)."""
        self.slots.admit(rid)
        toks = np.asarray(tokens, np.int32)
        if len(toks) == 0:
            # legal for one-behind drafter caches of a single-token prompt:
            # the slot holds the empty context; the first decode() fills it
            return None, 0.0
        sidx = self.slots.padded_idx([rid])
        rows = int(sidx.shape[0])
        chunk_len = prefill_chunk_len(self.cfg)
        logits = None
        ll_sum, ll_n = 0.0, 0
        i = 0
        while i < len(toks):
            n_real = min(chunk_len, len(toks) - i)
            width = min(prefill_bucket(n_real), chunk_len)
            if i + width > self.max_len:
                # a padded tail would spill past the cache capacity and
                # its ring columns could clobber live rows — fall back to
                # an exact-width write (prompt ~ max_len; one-off shape)
                width = n_real
            seg = np.zeros((rows, width), np.int32)
            seg[0, :n_real] = toks[i: i + n_real]
            mask = np.zeros((rows, width), bool)
            mask[0, :n_real] = True            # batch-pad rows stay masked
            pv = self.slots.prepare([rid], write=width)
            logits, self.slots.cache, _ = self._jit_slot_extend(
                self.params, tokens=jnp.asarray(seg), cache=self.slots.cache,
                slot_idx=sidx, token_mask=jnp.asarray(mask), page_view=pv)
            self.n_prefill_writes += 1
            self.slots.advance(rid, n_real)
            # likelihood of the *next* tokens within this chunk
            nxt = toks[i + 1: i + n_real]
            if len(nxt):
                lp = jax.nn.log_softmax(
                    logits[0, : len(nxt), : self.cfg.vocab], -1)
                ll_sum += float(jnp.take_along_axis(
                    lp, jnp.asarray(nxt)[:, None], -1).sum())
                ll_n += len(nxt)
            i += n_real
        mean_ll = ll_sum / max(ll_n, 1)
        # n_real is the final chunk's real-token count after the loop
        return np.asarray(logits[0, n_real - 1, : self.cfg.vocab]), mean_ll

    def prefill_requests(self, reqs: Dict[int, Sequence[int]]
                         ) -> Dict[int, tuple]:
        """Burst admission: prefill several cold requests with ONE masked
        `slot_extend` write — each request is a row, prompts padded to
        the common bucketed width with the pad masked out (the same
        suffix-pad mechanism the chunked single-request path uses per
        row). Prompts longer than one chunk, empty contexts (one-behind
        drafter caches of single-token prompts) and singleton bursts
        fall back to `prefill_request`. Returns {rid: (last-position
        logits, mean next-token logprob)}."""
        out: Dict[int, tuple] = {}
        chunk_len = min(prefill_chunk_len(self.cfg), self.max_len)
        batch: Dict[int, np.ndarray] = {}
        for rid, tokens in reqs.items():
            toks = np.asarray(tokens, np.int32)
            if 0 < len(toks) <= chunk_len:
                batch[rid] = toks
            else:
                out[rid] = self.prefill_request(rid, toks)
        if len(batch) == 1:
            rid, toks = next(iter(batch.items()))
            out[rid] = self.prefill_request(rid, toks)
            return out
        if not batch:
            return out
        for rid in batch:
            self.slots.admit(rid)
        rids = list(batch)
        sidx = self.slots.padded_idx(rids)
        rows = int(sidx.shape[0])
        maxn = max(len(t) for t in batch.values())
        width = min(prefill_bucket(maxn), chunk_len)
        seg = np.zeros((rows, width), np.int32)
        mask = np.zeros((rows, width), bool)
        for j, rid in enumerate(rids):
            t = batch[rid]
            seg[j, : len(t)] = t
            mask[j, : len(t)] = True
        pv = self.slots.prepare(rids, write=width)
        logits, self.slots.cache, _ = self._jit_slot_extend(
            self.params, tokens=jnp.asarray(seg), cache=self.slots.cache,
            slot_idx=sidx, token_mask=jnp.asarray(mask), page_view=pv)
        self.n_prefill_writes += 1
        for rid in rids:
            self.slots.advance(rid, len(batch[rid]))
        lp = np.asarray(jax.nn.log_softmax(
            logits[:, :, : self.cfg.vocab], -1))
        for j, rid in enumerate(rids):
            t = batch[rid]
            n = len(t)
            nxt = t[1:]
            ll = (float(np.take_along_axis(
                lp[j, : n - 1], nxt[:, None], -1).sum()) / (n - 1)
                if n > 1 else 0.0)
            out[rid] = (np.asarray(logits[j, n - 1, : self.cfg.vocab]), ll)
        return out

    def drop(self, rid: int):
        """Evict `rid`: slot (and pages, when paged) return to the pool."""
        self.slots.release(rid)

    # ----------------------------------------------------------- batched ops
    def speculative_caches(self, rids: Sequence[int]):
        """Device-side snapshot of the requests' slots as one compact
        batched cache (bucketed batch). Decoding on it never touches the
        slotted cache — discarding it is the speculative rollback. On a
        paged pool this gathers only the mapped pages (plus SNAP_SLACK
        columns of write headroom) into a plain stacked cache, so the
        snapshot copies tokens actually held, not reserved capacity."""
        idx = self.slots.padded_idx(rids)
        pv = self.slots.snapshot_view(rids)
        if pv is None:
            return _g_gather(self.slots.cache, idx)
        return self._jit_gather_paged(cache=self.slots.cache, slot_idx=idx,
                                      page_view=pv)

    def extend_snapshot(self, caches: dict, tokens: np.ndarray):
        """Teacher-force `tokens` (B, T) into a speculative snapshot
        (optimistic draft-ahead warm-up: replays an assumed context
        extension so chaining can continue past it). Exact time shapes
        (no padding along T — SSM-state safe); padded batch rows receive
        garbage that is never read. Returns (last logits (B, V), caches)."""
        B = tokens.shape[0]
        rows = int(caches["lengths"].shape[0])
        lg, caches, _ = self._jit_extend_plain(
            self.params,
            tokens=jnp.asarray(self._pad_rows(np.asarray(tokens, np.int32),
                                              rows)),
            cache=caches)
        return np.asarray(lg[:B, -1, : self.cfg.vocab]), caches

    def _pad_rows(self, a: np.ndarray, rows: int) -> np.ndarray:
        if a.shape[0] == rows:
            return a
        pad = np.zeros((rows - a.shape[0],) + a.shape[1:], a.dtype)
        return np.concatenate([a, pad], axis=0)

    def decode(self, rids: Sequence[int], tokens: np.ndarray,
               caches: Optional[dict] = None):
        """One decode step. tokens: (B,). Returns logits (B, V) and, when
        `caches` (a speculative snapshot) is passed, its updated copy;
        otherwise the slotted cache is updated in place and None returned."""
        B = len(rids)
        toks = np.asarray(tokens, np.int32)
        if caches is not None:
            rows = int(caches["lengths"].shape[0])
            lg, new_cache, _ = self._jit_decode(
                self.params,
                tokens=jnp.asarray(self._pad_rows(toks, rows))[:, None],
                cache=caches)
        else:
            sidx = self.slots.padded_idx(rids)
            pv = self.slots.prepare(rids, write=1)
            lg, self.slots.cache, _ = self._jit_slot_decode(
                self.params,
                tokens=jnp.asarray(self._pad_rows(toks, sidx.shape[0]))[:, None],
                cache=self.slots.cache, slot_idx=sidx, page_view=pv)
            for r in rids:
                self.slots.advance(r, 1)
            new_cache = None
        return np.asarray(lg[:B, 0, : self.cfg.vocab]), new_cache

    def verify_device(self, rids: Sequence[int], tokens: np.ndarray,
                      rel_pos: np.ndarray, seg_mask: np.ndarray):
        """Tree/chain verification forward, result left on device (rows
        x Gmax x padded vocab) — the async backend's worker dispatches
        this and defers the host transfer (`device_get`) until the
        acceptance walk actually consumes the logits."""
        B, G = tokens.shape
        sidx = self.slots.padded_idx(rids)
        rows = int(sidx.shape[0])
        mask = np.asarray(seg_mask, bool)
        if rows != B:
            # padded (scratch) rows verify a lower-triangular dummy segment
            mask = np.concatenate(
                [mask, np.broadcast_to(np.tril(np.ones((G, G), bool)),
                                       (rows - B, G, G))], axis=0)
        pv = self.slots.prepare(rids, write=0)
        return self._jit_slot_verify(
            self.params,
            tokens=jnp.asarray(self._pad_rows(np.asarray(tokens, np.int32),
                                              rows)),
            cache=self.slots.cache, slot_idx=sidx,
            rel_pos=jnp.asarray(self._pad_rows(np.asarray(rel_pos, np.int32),
                                               rows)),
            seg_mask=jnp.asarray(mask), page_view=pv)

    def verify(self, rids: Sequence[int], tokens: np.ndarray,
               rel_pos: np.ndarray, seg_mask: np.ndarray) -> np.ndarray:
        """Tree/chain verification (no cache commit).

        tokens: (B, Gmax); rel_pos: (B, Gmax) node depths; seg_mask
        (B, Gmax, Gmax) ancestor mask. Returns logits (B, Gmax, V)."""
        B = tokens.shape[0]
        lg = self.verify_device(rids, tokens, rel_pos, seg_mask)
        return np.asarray(lg[:B, :, : self.cfg.vocab])

    def extend_committed(self, rid_tokens: Dict[int, List[int]]) -> Dict[int, np.ndarray]:
        """Commit accepted tokens per request into the slotted cache;
        returns each request's post-commit tail logits (V,). Groups by
        token-count so shapes stay exact (SSM-state safe)."""
        out: Dict[int, np.ndarray] = {}
        by_len: Dict[int, List[int]] = {}
        for rid, toks in rid_tokens.items():
            by_len.setdefault(len(toks), []).append(rid)
        for n, rids in by_len.items():
            if n == 0:
                continue
            sidx = self.slots.padded_idx(rids)
            toks = np.asarray([rid_tokens[r] for r in rids], np.int32)
            pv = self.slots.prepare(rids, write=n)
            lg, self.slots.cache, _ = self._jit_slot_extend(
                self.params,
                tokens=jnp.asarray(self._pad_rows(toks, int(sidx.shape[0]))),
                cache=self.slots.cache, slot_idx=sidx, page_view=pv)
            for i, r in enumerate(rids):
                out[r] = np.asarray(lg[i, -1, : self.cfg.vocab])
                self.slots.advance(r, n)
        return out

    def length(self, rid: int) -> int:
        """Committed tokens for `rid`."""
        return self.slots.length(rid)
