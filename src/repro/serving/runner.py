"""ModelRunner: executes one model (target LLM or drafter SSM) over
per-request KV caches with jit-compiled, shape-bucketed step functions.

Caches are per-request (batch dim 1) pytrees from `model.init_cache`;
batched calls stack them along axis 0, run one jitted program, and split
back — functional continuous batching. Rollback is snapshot-based: the
engine simply keeps the pre-draft cache object and discards speculative
ones (correct for both attention KV and SSM recurrent state).
"""
from __future__ import annotations

from functools import partial
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import model as M

PREFILL_BUCKETS = (8, 16, 32, 64, 128, 256, 512, 1024)


_stack = M.stack_caches
_split = M.split_cache

# Module-level jitted steps with cfg static: every ModelRunner with the
# same (hashable, frozen) ModelConfig shares one compile cache — engines
# are created freely in benchmarks without re-tracing.
_g_prefill = jax.jit(M.prefill, static_argnames=("cfg",))
_g_decode = jax.jit(M.decode_step, static_argnames=("cfg",))
_g_verify = jax.jit(M.verify_chunk, static_argnames=("cfg", "write"))
_g_extend = jax.jit(M.extend, static_argnames=("cfg",))


class ModelRunner:
    def __init__(self, cfg: ModelConfig, params, max_len: int = 512,
                 cache_dtype=jnp.float32):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.cache_dtype = cache_dtype
        self.caches: Dict[int, dict] = {}
        self.embed_np = np.asarray(params["embed"][: cfg.vocab], np.float32)

        self._jit_prefill = partial(_g_prefill, cfg=cfg)
        self._jit_decode = partial(_g_decode, cfg=cfg)
        self._jit_verify = partial(_g_verify, cfg=cfg)
        self._jit_extend = partial(_g_extend, cfg=cfg)

    # ----------------------------------------------------------- lifecycle
    def new_cache(self):
        return M.init_cache(self.cfg, 1, self.max_len, dtype=self.cache_dtype)

    def prefill_request(self, rid: int, tokens: np.ndarray):
        """Prefill a request's context; returns (last-position logits (V,),
        mean next-token logprob of the context under this model).

        The logprob is the engine's content-based routing prior (paper §5:
        requests are analyzed and matched to suitable drafters before
        inference). Runs in shape buckets (exact coverage — no padded
        garbage reaches SSM states)."""
        cache = self.new_cache()
        toks = np.asarray(tokens, np.int32)
        logits = None
        ll_sum, ll_n = 0.0, 0
        i = 0
        while i < len(toks):
            remaining = len(toks) - i
            chunk = 1
            for b in PREFILL_BUCKETS:
                if b <= remaining:
                    chunk = b
            seg = jnp.asarray(toks[i: i + chunk])[None, :]
            if chunk == 1 and i > 0:
                logits, cache, _ = self._jit_decode(self.params, tokens=seg,
                                                    cache=cache)
            else:
                logits, cache, _ = self._jit_extend(self.params, tokens=seg,
                                                    cache=cache)
            # likelihood of the *next* tokens within this chunk
            nxt = toks[i + 1: i + chunk]
            if len(nxt):
                lp = jax.nn.log_softmax(
                    logits[0, : len(nxt), : self.cfg.vocab], -1)
                ll_sum += float(jnp.take_along_axis(
                    lp, jnp.asarray(nxt)[:, None], -1).sum())
                ll_n += len(nxt)
            i += chunk
        self.caches[rid] = cache
        mean_ll = ll_sum / max(ll_n, 1)
        return np.asarray(logits[0, -1, : self.cfg.vocab]), mean_ll

    def drop(self, rid: int):
        self.caches.pop(rid, None)

    # ----------------------------------------------------------- batched ops
    def decode(self, rids: Sequence[int], tokens: np.ndarray,
               caches: Optional[dict] = None):
        """One decode step. tokens: (B,). Returns logits (B, V) and updates
        (or returns, if `caches` passed) the stacked cache."""
        stacked = caches if caches is not None else _stack(
            [self.caches[r] for r in rids])
        lg, new_cache, _ = self._jit_decode(
            self.params, tokens=jnp.asarray(tokens, jnp.int32)[:, None],
            cache=stacked)
        if caches is None:
            for r, c in zip(rids, _split(new_cache, len(rids))):
                self.caches[r] = c
            new_cache = None
        return np.asarray(lg[:, 0, : self.cfg.vocab]), new_cache

    def verify(self, rids: Sequence[int], tokens: np.ndarray,
               rel_pos: np.ndarray, seg_mask: np.ndarray) -> np.ndarray:
        """Tree/chain verification (no cache commit).

        tokens: (B, Gmax); rel_pos: (B, Gmax) node depths; seg_mask
        (B, Gmax, Gmax) ancestor mask. Returns logits (B, Gmax, V)."""
        stacked = _stack([self.caches[r] for r in rids])
        positions = stacked["lengths"][:, None] + jnp.asarray(rel_pos, jnp.int32)
        lg, _, _ = self._jit_verify(
            self.params, tokens=jnp.asarray(tokens, jnp.int32),
            cache=stacked, positions=positions,
            seg_mask=jnp.asarray(seg_mask), write=False)
        return np.asarray(lg[..., : self.cfg.vocab])

    def extend_committed(self, rid_tokens: Dict[int, List[int]]) -> Dict[int, np.ndarray]:
        """Commit accepted tokens per request; returns each request's
        post-commit tail logits (V,). Groups by token-count so shapes stay
        exact (SSM-state safe)."""
        out: Dict[int, np.ndarray] = {}
        by_len: Dict[int, List[int]] = {}
        for rid, toks in rid_tokens.items():
            by_len.setdefault(len(toks), []).append(rid)
        for n, rids in by_len.items():
            if n == 0:
                continue
            stacked = _stack([self.caches[r] for r in rids])
            toks = jnp.asarray([rid_tokens[r] for r in rids], jnp.int32)
            lg, new_cache, _ = self._jit_extend(self.params, tokens=toks,
                                                cache=stacked)
            for i, (r, c) in enumerate(zip(rids, _split(new_cache, len(rids)))):
                self.caches[r] = c
                out[r] = np.asarray(lg[i, -1, : self.cfg.vocab])
        return out

    def length(self, rid: int) -> int:
        return int(self.caches[rid]["lengths"][0])
