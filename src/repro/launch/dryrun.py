import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input shape) on the production meshes, record
memory/cost/collective analysis for the roofline (deliverable g).

The two lines above MUST stay the first statements in this file — jax
locks the device count on first init (see the brief).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b --shape decode_32k --multi-pod
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--step verify]
Writes experiments/dryrun/<arch>__<shape>__<mesh>[__verify].json
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import INPUT_SHAPES, ModelConfig
from repro.configs import LONG_CONTEXT_POLICY, get_config
from repro.distributed import sharding as sh
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.optim.optimizers import adamw, apply_updates, sgd

DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# hardware constants (brief): TPU v5e
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link


def resolve_config(arch: str, shape: str, variant: str = "") -> ModelConfig:
    """variant: comma-list of perf levers (EXPERIMENTS.md §Perf):
      parallel — flash-decoding parallel-partial attention
      seqkv    — sequence-parallel KV sharding (pairs with parallel)
      int8     — int8-quantized KV cache
    """
    cfg = get_config(arch)
    if shape == "long_500k" and LONG_CONTEXT_POLICY[arch] == "swa":
        cfg = cfg.with_overrides(long_context="swa")
    v = set(filter(None, variant.split(",")))
    if "parallel" in v or "seqkv" in v:
        cfg = cfg.with_overrides(decode_attn="parallel")
    if "int8" in v:
        cfg = cfg.with_overrides(kv_dtype="int8")
    if "moegather" in v:
        cfg = cfg.with_overrides(moe_dispatch="gather_tokens")
    return cfg


def frontend_struct(cfg: ModelConfig, batch: int):
    if cfg.n_frontend_tokens:
        return jax.ShapeDtypeStruct((batch, cfg.n_frontend_tokens,
                                     cfg.d_model), jnp.bfloat16)
    return None


def param_structs(cfg: ModelConfig, dtype=jnp.bfloat16):
    shapes = jax.eval_shape(lambda k: M.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, dtype if jnp.issubdtype(s.dtype, jnp.floating) else s.dtype),
        shapes)


def n_params_of(cfg: ModelConfig) -> int:
    import math
    shapes = jax.eval_shape(lambda k: M.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    return sum(math.prod(s.shape) for s in jax.tree.leaves(shapes))


def active_params_of(cfg: ModelConfig) -> int:
    """Parameters touched per token (MoE: shared + top_k routed)."""
    total = n_params_of(cfg)
    if cfg.moe is None:
        return total
    moe = cfg.moe
    n_moe_layers = sum(1 for i in range(cfg.n_layers) if cfg.is_moe_layer(i))
    per_expert = 3 * cfg.d_model * moe.d_ff
    inactive = n_moe_layers * (moe.n_routed - moe.top_k) * per_expert
    return total - inactive


# --------------------------------------------------------------- step fns

def make_step(cfg: ModelConfig, shape_name: str, mesh, step_kind: str,
              variant: str = ""):
    """Returns (fn, arg_structs, in_shardings)."""
    v = set(filter(None, variant.split(",")))
    ishape = INPUT_SHAPES[shape_name]
    B, S = ishape.global_batch, ishape.seq_len
    bspec = sh.batch_spec(mesh, B)
    mode = "train" if step_kind == "train" else "serve"
    pspecs = sh.param_specs(cfg, mesh, mode=mode,
                            moe_axis="model" if "epmodel" in v else "data",
                            head_align="headalign" in v)
    pstructs = param_structs(cfg, jnp.bfloat16)
    p_shard = sh.to_named(pspecs, mesh)
    fe = frontend_struct(cfg, B)
    fe_shard = NamedSharding(mesh, P(bspec, None, None)) if fe is not None else None

    if step_kind == "train":
        big = n_params_of(cfg) > 10_000_000_000
        opt = sgd(lr=1e-3, momentum=0.0) if big else adamw(1e-4)

        def train_step(params, opt_state, tokens, frontend=None):
            (loss, parts), grads = jax.value_and_grad(M.lm_loss, has_aux=True)(
                params, cfg, tokens, frontend=frontend, remat=True)
            updates, opt_state = opt.update(grads, opt_state, params)
            params = apply_updates(params, updates)
            return params, opt_state, loss

        tokens = jax.ShapeDtypeStruct((B, S), jnp.int32)
        t_shard = NamedSharding(mesh, P(bspec, None))
        if big:
            opt_structs, o_shard = None, None
        else:
            f32 = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), pstructs)
            opt_structs = {"m": f32, "v": f32,
                           "t": jax.ShapeDtypeStruct((), jnp.int32)}
            o_shard = {"m": p_shard, "v": p_shard,
                       "t": NamedSharding(mesh, P())}
        args = [pstructs, opt_structs, tokens]
        shards = [p_shard, o_shard, t_shard]
        if fe is not None:
            args.append(fe)
            shards.append(fe_shard)
        return train_step, args, shards

    # serving steps need a cache
    if shape_name == "long_500k":
        from repro.models.model import effective_window
        win = effective_window(cfg)
        cap = (win + 128) if win else S + 128
    else:
        cap = S + 128
    if "seqkv" in v:
        n_model = mesh.shape["model"]
        cap = ((cap + n_model - 1) // n_model) * n_model
        cfg = cfg.with_overrides(decode_block=cap // n_model)
    cache_structs, cspecs = sh.cache_specs(
        cfg, mesh, B, cap, dtype=jnp.bfloat16,
        kv_shard="seq" if "seqkv" in v else "auto")
    c_shard = sh.to_named(cspecs, mesh)

    if step_kind == "prefill":
        def prefill_step(params, tokens, frontend=None):
            cache = M.init_cache(cfg, B, cap, dtype=jnp.bfloat16)
            logits, cache, _ = M.prefill(params, cfg, tokens, cache,
                                         frontend=frontend)
            return logits[:, -1], cache

        tokens = jax.ShapeDtypeStruct((B, S), jnp.int32)
        t_shard = NamedSharding(mesh, P(bspec, None))
        args = [pstructs, tokens]
        shards = [p_shard, t_shard]
        if fe is not None:
            args.append(fe)
            shards.append(fe_shard)
        return prefill_step, args, shards

    if step_kind == "decode":
        def serve_step(params, tokens, cache):
            logits, cache, _ = M.decode_step(params, cfg, tokens, cache)
            return logits, cache

        tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        t_shard = NamedSharding(mesh, P(bspec, None))
        return serve_step, [pstructs, tokens, cache_structs], \
            [p_shard, t_shard, c_shard]

    if step_kind == "verify":
        GAMMA = 16  # CoSine tree nodes per request per iteration

        def verify_step(params, tokens, cache):
            logits, _, _ = M.verify_chunk(params, cfg, tokens, cache,
                                          write=False)
            return logits

        tokens = jax.ShapeDtypeStruct((B, GAMMA), jnp.int32)
        t_shard = NamedSharding(mesh, P(bspec, None))
        return verify_step, [pstructs, tokens, cache_structs], \
            [p_shard, t_shard, c_shard]

    raise KeyError(step_kind)


# --------------------------------------------------------------- analysis

def collective_bytes(hlo_text: str):
    """Sum result-shape bytes of every collective op in the partitioned HLO."""
    out = {c: 0.0 for c in COLLECTIVES}
    counts = {c: 0 for c in COLLECTIVES}
    shape_re = re.compile(r"(f64|f32|f16|bf16|s64|u64|s32|u32|s16|u16|s8|u8|"
                          r"pred|f8e4m3fn|f8e5m2)\[([0-9,]*)\]")
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)$", line)
        if not m:
            continue
        rhs = m.group(1)
        op = None
        for c in COLLECTIVES:
            if re.search(rf"\b{c}(-start|-done)?\(", rhs):
                op = c
                break
        if op is None or f"{op}-done(" in rhs:
            continue
        # result shapes appear before the op name
        head = rhs.split(op)[0]
        nbytes = 0
        for dt, dims in shape_re.findall(head):
            size = 1
            for d in dims.split(","):
                if d:
                    size *= int(d)
            nbytes += size * DTYPE_BYTES[dt]
        out[op] += nbytes
        counts[op] += 1
    return out, counts


def step_kind_for(shape_name: str) -> str:
    return {"train": "train", "prefill": "prefill",
            "decode": "decode"}[INPUT_SHAPES[shape_name].kind]


def run_one(arch: str, shape_name: str, multi_pod: bool = False,
            step_override: str | None = None, out_dir: str = "experiments/dryrun",
            save_hlo: bool = False, variant: str = "") -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = resolve_config(arch, shape_name, variant)
    kind = step_override or step_kind_for(shape_name)
    t0 = time.time()
    fn, args, shards = make_step(cfg, shape_name, mesh, kind, variant)

    with mesh:
        jitted = jax.jit(fn, in_shardings=tuple(shards))
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    coll_raw, _ = collective_bytes(hlo)
    from repro.analysis.hlo import collective_bytes_corrected
    coll, coll_counts = collective_bytes_corrected(hlo)

    n_chips = mesh.devices.size
    ishape = INPUT_SHAPES[shape_name]
    flops_hlo = float(cost.get("flops", 0.0))
    bytes_hlo = float(cost.get("bytes accessed", 0.0))
    total_coll = sum(coll.values())      # per-device, trip-corrected

    n_total = n_params_of(cfg)
    n_active = active_params_of(cfg)
    if kind == "train":
        tokens_processed = ishape.global_batch * ishape.seq_len
        model_flops = 6 * n_active * tokens_processed
    elif kind == "prefill":
        tokens_processed = ishape.global_batch * ishape.seq_len
        model_flops = 2 * n_active * tokens_processed
    else:
        tokens_processed = ishape.global_batch * (16 if kind == "verify" else 1)
        model_flops = 2 * n_active * tokens_processed

    # Primary terms: analytic closed forms (XLA cost analysis counts scan
    # bodies once -> under-counts by ~n_layers; see analysis/analytic.py).
    from repro.analysis.analytic import estimate
    est = estimate(cfg, shape_name, kind, n_active, n_total)
    compute_s = est.flops / (n_chips * PEAK_FLOPS)
    memory_s = est.hbm_bytes / (n_chips * HBM_BW)
    # corrected collective bytes are from the per-device program; each
    # chip pushes its share over its own links
    collective_s = total_coll / ICI_BW

    result = {
        "arch": arch, "shape": shape_name, "step": kind,
        "mesh": "2x16x16" if multi_pod else "16x16", "n_chips": int(n_chips),
        "ok": True,
        "t_lower_s": round(t_lower, 2), "t_compile_s": round(t_compile, 2),
        "n_params": n_total, "n_active_params": n_active,
        "analytic": {"flops_global": est.flops,
                     "hbm_bytes_global": est.hbm_bytes},
        "per_device": {
            "hlo_flops_scanbody_once": flops_hlo,
            "hlo_bytes_scanbody_once": bytes_hlo,
            "collective_bytes_corrected": coll,
            "collective_bytes_raw": coll_raw,
            "collective_counts": coll_counts,
            "collective_bytes_total": total_coll,
        },
        "memory_analysis": {
            k: getattr(mem, k, None) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "alias_size_in_bytes",
             "generated_code_size_in_bytes")
        } if mem is not None else None,
        "roofline": {
            "compute_s": compute_s, "memory_s": memory_s,
            "collective_s": collective_s,
            "dominant": max(
                [("compute", compute_s), ("memory", memory_s),
                 ("collective", collective_s)], key=lambda kv: kv[1])[0],
        },
        "model_flops_global": model_flops,
        "useful_flops_ratio": (model_flops / est.flops
                               if est.flops else None),
    }

    result["variant"] = variant
    os.makedirs(out_dir, exist_ok=True)
    suffix = "" if step_override is None else f"__{step_override}"
    if variant:
        suffix += f"__v-{variant.replace(',', '+')}"
    name = f"{arch}__{shape_name}__{result['mesh']}{suffix}"
    with open(os.path.join(out_dir, name + ".json"), "w") as f:
        json.dump(result, f, indent=1)
    if save_hlo:
        with open(os.path.join(out_dir, name + ".hlo"), "w") as f:
            f.write(hlo)
    print(f"[dryrun] {name}: OK lower={t_lower:.1f}s compile={t_compile:.1f}s "
          f"dominant={result['roofline']['dominant']}")
    if mem is not None:
        print(f"  memory_analysis: args={getattr(mem, 'argument_size_in_bytes', None)} "
              f"temp={getattr(mem, 'temp_size_in_bytes', None)} "
              f"out={getattr(mem, 'output_size_in_bytes', None)}")
    print(f"  analytic: flops={est.flops:.3e} hbm={est.hbm_bytes:.3e} "
          f"coll/dev={total_coll:.3e}")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--step", type=str, default=None,
                    help="override step kind (e.g. verify)")
    ap.add_argument("--out", type=str, default="experiments/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--variant", type=str, default="",
                    help="comma list: parallel,seqkv,int8 (§Perf levers)")
    args = ap.parse_args()

    if args.all:
        from repro.configs import arch_shape_pairs
        failures = []
        for arch, shape in arch_shape_pairs():
            mesh_name = "2x16x16" if args.multi_pod else "16x16"
            suffix = "" if args.step is None else f"__{args.step}"
            if args.variant:
                suffix += f"__v-{args.variant.replace(',', '+')}"
            path = os.path.join(args.out,
                                f"{arch}__{shape}__{mesh_name}{suffix}.json")
            if args.skip_existing and os.path.exists(path):
                continue
            try:
                run_one(arch, shape, args.multi_pod, args.step, args.out,
                        args.save_hlo, args.variant)
            except Exception as e:
                failures.append((arch, shape, repr(e)))
                print(f"[dryrun] {arch}/{shape} FAILED: {e}")
                traceback.print_exc()
        if failures:
            print(f"{len(failures)} FAILURES:")
            for f in failures:
                print(" ", f)
            raise SystemExit(1)
        print("all combos lowered + compiled OK")
    else:
        run_one(args.arch, args.shape, args.multi_pod, args.step, args.out,
                args.save_hlo, args.variant)


if __name__ == "__main__":
    main()
