"""Serving launcher: bring up a CoSine deployment from checkpoints (or
freshly trained tiny models) and serve a synthetic request stream.

  PYTHONPATH=src python -m repro.launch.serve --strategy cosine --requests 8
  PYTHONPATH=src python -m repro.launch.serve --ckpt-dir checkpoints \
      --strategy cosine --mode volatile
"""
from __future__ import annotations

import argparse
import os

import numpy as np

from repro.checkpoint.store import load_checkpoint
from repro.config import CoSineConfig
from repro.configs.drafters import tiny_drafter, tiny_target
from repro.data.synthetic import DOMAINS, SyntheticCorpus
from repro.serving.engine import STRATEGIES, SpeculativeEngine

VOCAB = 96


def build_models(ckpt_dir, corpus, steps):
    from repro.launch.train import train_model
    tcfg, dcfg = tiny_target(VOCAB), tiny_drafter(VOCAB)
    if ckpt_dir and os.path.exists(os.path.join(ckpt_dir, "target.msgpack")):
        tparams, _ = load_checkpoint(os.path.join(ckpt_dir, "target.msgpack"))
        drafters = []
        for dom in DOMAINS:
            dp, _ = load_checkpoint(
                os.path.join(ckpt_dir, f"drafter_{dom}.msgpack"))
            drafters.append((dcfg, dp, dom))
        return (tcfg, tparams), drafters
    print("(no checkpoints found — training tiny models inline)")
    tparams, _ = train_model(tcfg, corpus, None, steps * 2, batch=16, seq=64,
                             verbose=False)
    drafters = []
    for i, dom in enumerate(DOMAINS):
        dp, _ = train_model(dcfg, corpus, dom, steps, batch=16, seq=64,
                            seed=i + 1, verbose=False)
        drafters.append((dcfg, dp, dom))
    return (tcfg, tparams), drafters


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--strategy", choices=STRATEGIES, default="cosine")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--mode", choices=["offline", "low", "high", "volatile"],
                    default="offline")
    ap.add_argument("--ckpt-dir", type=str, default="checkpoints")
    ap.add_argument("--train-steps", type=int, default=150)
    ap.add_argument("--draft-len", type=int, default=5)
    ap.add_argument("--drafters-per-request", type=int, default=2)
    args = ap.parse_args()

    corpus = SyntheticCorpus(VOCAB, seed=0, sharpness=120.0, support=5)
    target, drafters = build_models(args.ckpt_dir, corpus, args.train_steps)
    cos = CoSineConfig(n_drafters=len(drafters), draft_len=args.draft_len,
                       drafters_per_request=args.drafters_per_request,
                       tree_width=2)
    eng = SpeculativeEngine(target, drafters, cos, strategy=args.strategy,
                            max_len=512)

    if args.mode == "offline":
        arrivals = np.zeros(args.requests)
    else:
        import sys
        sys.path.insert(0, "benchmarks")
        from benchmarks.online_serving import make_arrivals
        arrivals = make_arrivals(args.mode, args.requests, seed=5)

    for (p, dom), t in zip(corpus.prompts(args.requests, 16, seed=13),
                           arrivals):
        eng.submit(p, max_new_tokens=args.max_new, domain=dom,
                   arrival_ms=float(t))
    stats = eng.run()
    lat = [(r.finish_ms - r.arrival_ms) / max(len(r.generated), 1)
           for r in eng.pool.completed]
    print(f"strategy={args.strategy} requests={len(eng.pool.completed)} "
          f"tokens={stats.total_committed}")
    print(f"  throughput {stats.throughput_tps:.1f} tok/s | "
          f"latency {np.mean(lat):.1f} ms/tok (p95 {np.percentile(lat, 95):.1f}) | "
          f"acceptance {stats.mean_acceptance:.2f} tokens/iteration")


if __name__ == "__main__":
    main()
