"""Training loop: drafter domain fine-tuning and target pretraining on the
synthetic multi-domain corpus, plus the generic (shardable) train_step used
by the multi-pod dry-run.

Usage (CPU example driver):
  PYTHONPATH=src python -m repro.launch.train --arch tiny --steps 200
"""
from __future__ import annotations

import argparse
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.data.synthetic import SyntheticCorpus, token_batches
from repro.models import model as M
from repro.optim.optimizers import Optimizer, apply_updates, get_optimizer


def make_train_step(cfg: ModelConfig, opt: Optimizer, remat: bool = True):
    """Returns train_step(params, opt_state, batch[, frontend]) ->
    (params, opt_state, metrics). jit/pjit-able as is."""

    def train_step(params, opt_state, tokens, frontend=None):
        (loss, parts), grads = jax.value_and_grad(
            M.lm_loss, has_aux=True)(params, cfg, tokens, frontend=frontend,
                                     remat=remat)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, {"loss": loss, **parts}

    return train_step


def train_model(cfg: ModelConfig, corpus: SyntheticCorpus,
                domain: Optional[str], steps: int, batch: int = 8,
                seq: int = 64, lr: float = 3e-3, seed: int = 0,
                optimizer: str = "adamw", params=None, log_every: int = 50,
                verbose: bool = True):
    """Train (or fine-tune, if params given) on one domain or the mixture."""
    key = jax.random.PRNGKey(seed)
    if params is None:
        params = M.init_params(key, cfg)
    opt = get_optimizer(optimizer, lr)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt, remat=False))

    losses = []
    for i, rows in enumerate(token_batches(corpus, domain, batch, seq, steps)):
        params, opt_state, metrics = step_fn(params, opt_state,
                                             jnp.asarray(rows))
        losses.append(float(metrics["loss"]))
        if verbose and (i % log_every == 0 or i == steps - 1):
            print(f"  [{cfg.name}|{domain or 'mixture'}] step {i:4d} "
                  f"loss {losses[-1]:.4f}")
    return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--vocab", type=int, default=256)
    ap.add_argument("--domain", type=str, default=None)
    args = ap.parse_args()

    from repro.configs.drafters import tiny_target
    cfg = tiny_target(args.vocab)
    corpus = SyntheticCorpus(args.vocab)
    params, losses = train_model(cfg, corpus, args.domain, args.steps,
                                 args.batch, args.seq)
    print(f"final loss: {losses[-1]:.4f} (start {losses[0]:.4f})")


if __name__ == "__main__":
    main()
