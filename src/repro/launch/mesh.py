"""Production mesh builders (functions only — importing this module never
touches jax device state).

Single pod: 16 x 16 = 256 chips ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips ("pod", "data", "model") — the "pod"
axis carries extra data parallelism (per-pod FSDP groups; DCN-friendly:
only gradient all-reduce crosses pods in training, nothing in serving).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def batch_axes(mesh) -> tuple:
    """Axis names over which the global batch is sharded."""
    return tuple(n for n in mesh.axis_names if n in ("pod", "data"))


def axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1
