"""Optimizers (pure JAX, pytree-based): AdamW, SGD+momentum, Adafactor.

Adafactor exists so 50B+ parameter train dry-runs fit v5e HBM (optimizer
state is O(sum of matrix dims) instead of 2x params).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]   # (grads, state, params) -> (updates, state)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def sgd(lr: float = 1e-2, momentum: float = 0.9) -> Optimizer:
    def init(params):
        return jax.tree.map(jnp.zeros_like, params) if momentum else None

    def update(grads, state, params=None):
        if momentum:
            state = jax.tree.map(lambda m, g: momentum * m + g, state, grads)
            upd = jax.tree.map(lambda m: -lr * m, state)
        else:
            upd = jax.tree.map(lambda g: -lr * g, grads)
        return upd, state

    return Optimizer(init, update)


def adamw(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.999,
          eps: float = 1e-8, weight_decay: float = 0.01) -> Optimizer:
    def init(params):
        zeros = lambda: jax.tree.map(
            lambda p: jnp.zeros_like(p, jnp.float32), params)
        return {"m": zeros(), "v": zeros(), "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        t = state["t"] + 1
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(
            g.astype(jnp.float32)), state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def u(m, v, p):
            step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            return -lr * (step + weight_decay * p.astype(jnp.float32))

        upd = jax.tree.map(u, m, v, params)
        return upd, {"m": m, "v": v, "t": t}

    return Optimizer(init, update)


def adafactor(lr: float = 1e-2, eps: float = 1e-30,
              decay: float = 0.8, clip_threshold: float = 1.0) -> Optimizer:
    """Factored second-moment optimizer (Shazeer & Stern)."""
    def is_factored(p):
        return p.ndim >= 2 and p.shape[-1] >= 8 and p.shape[-2] >= 8

    def init(params):
        def one(p):
            if is_factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros_like(p, jnp.float32)}
        return {"s": jax.tree.map(one, params,
                                  is_leaf=lambda x: isinstance(x, jnp.ndarray)),
                "t": jnp.zeros((), jnp.int32)}

    def _state_leaf(x):
        return isinstance(x, dict) and ("v" in x or "vr" in x)

    def update(grads, state, params):
        t = state["t"] + 1
        beta = 1.0 - (t.astype(jnp.float32) + 1.0) ** -decay

        def one(s, g):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if "vr" in s:
                vr = beta * s["vr"] + (1 - beta) * g2.mean(-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(vr.mean(-1)[..., None, None], eps))
                upd = g * jax.lax.rsqrt(denom + eps)
                ns = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                upd = g * jax.lax.rsqrt(v + eps)
                ns = {"v": v}
            rms = jnp.sqrt(jnp.mean(jnp.square(upd)) + eps)
            upd = upd / jnp.maximum(1.0, rms / clip_threshold)
            return {"__u": -lr * upd, "__s": ns}

        pairs = jax.tree.map(one, state["s"], grads, is_leaf=_state_leaf)
        is_pair = lambda x: isinstance(x, dict) and "__u" in x
        upd = jax.tree.map(lambda pr: pr["__u"], pairs, is_leaf=is_pair)
        news = jax.tree.map(lambda pr: pr["__s"], pairs, is_leaf=is_pair)
        return upd, {"s": news, "t": t}

    return Optimizer(init, update)


def get_optimizer(name: str, lr: float) -> Optimizer:
    if name == "adamw":
        return adamw(lr)
    if name == "sgd":
        return sgd(lr)
    if name == "adafactor":
        return adafactor(lr)
    raise KeyError(name)
