"""Analytic FLOP / HBM-byte models per (arch, shape, step).

Why analytic: XLA's HloCostAnalysis counts while-loop bodies once, so a
scan-over-layers model under-reports flops/bytes by ~n_layers on the CPU
dry-run backend (EXPERIMENTS.md §Roofline documents the cross-check).
These closed forms are the primary compute/memory roofline terms; the
collective term comes from the trip-corrected HLO parse (analysis/hlo.py).

Conventions: ideal causal attention (half the square), bf16 tensors,
MoE counts only active (shared + top-k) experts, remat adds one forward
recompute to training.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.config import INPUT_SHAPES, ModelConfig


def _per_token_matmul_flops(cfg: ModelConfig) -> float:
    """2 * active-params matmul flops per token (excluding attention
    score/value products)."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    total = 2.0 * d * cfg.padded_vocab            # unembedding
    for i in range(cfg.n_layers):
        kind = cfg.layer_kind(i)
        if kind == "attn":
            if cfg.attention == "mla":
                m = cfg.mla
                total += 2 * d * m.q_lora_rank
                total += 2 * m.q_lora_rank * hq * m.qk_head_dim
                total += 2 * d * (m.kv_lora_rank + m.qk_rope_head_dim)
                # absorbed q/out projections (per-token, per-head latent)
                total += 2 * hq * m.qk_nope_head_dim * m.kv_lora_rank * 2
                total += 2 * hq * m.v_head_dim * d
            else:
                total += 2 * d * (hq + 2 * hkv) * hd + 2 * hq * hd * d
        else:  # ssm mixer
            s = cfg.ssm
            din = s.d_inner(d)
            total += 2 * d * (2 * din + 2 * s.n_groups * s.d_state
                              + s.n_heads(d))
            total += 2 * din * d
            # SSD state update+readout: 2 * d_inner * d_state each
            total += 4 * din * s.d_state
        if cfg.is_cross_layer(i) or cfg.is_encdec:
            total += 2 * d * (hq + hkv * 2) * hd + 2 * hq * hd * d
        if cfg.is_moe_layer(i):
            moe = cfg.moe
            total += 2 * 3 * d * (moe.top_k * moe.d_ff + moe.shared_width)
            total += 2 * d * moe.n_routed  # router
        elif cfg.layer_kind(i) == "attn" or cfg.d_ff:
            mult = 3 if cfg.mlp_type == "swiglu" else 2
            total += 2 * mult * d * cfg.d_ff
    return total


def _attn_context_flops(cfg: ModelConfig, q_tokens: float,
                        kv_len: float, causal: bool) -> float:
    """QK^T + PV flops for q_tokens queries against kv_len keys (per seq)."""
    hq, hd = cfg.n_heads, cfg.resolved_head_dim
    if cfg.attention == "mla":
        hd_eff = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
        hd_v = cfg.mla.kv_lora_rank
    else:
        hd_eff = hd_v = hd
    pairs = q_tokens * kv_len * (0.5 if causal and q_tokens == kv_len else 1.0)
    n_attn = sum(1 for i in range(cfg.n_layers) if cfg.layer_kind(i) == "attn")
    per_layer = 2 * pairs * hq * (hd_eff + hd_v)
    cross = 0.0
    if cfg.cross_attn_period or cfg.is_encdec:
        n_cross = sum(1 for i in range(cfg.n_layers)
                      if cfg.is_cross_layer(i) or cfg.is_encdec)
        cross = n_cross * 2 * q_tokens * cfg.n_frontend_tokens * hq * 2 * hd
    return n_attn * per_layer + cross


def _kv_cache_bytes(cfg: ModelConfig, kv_len: float, batch: float,
                    dtype_bytes: int = 0) -> float:
    from repro.models.model import effective_window
    if not dtype_bytes:
        dtype_bytes = 1 if cfg.kv_dtype == "int8" else 2
    win = effective_window(cfg)
    total = 0.0
    for i in range(cfg.n_layers):
        if cfg.layer_kind(i) == "attn":
            eff = min(kv_len, win + 128) if win else kv_len
            if cfg.attention == "mla":
                per_tok = cfg.mla.cache_dim * 2  # k_eff + v_eff rows
            else:
                per_tok = 2 * cfg.n_kv_heads * cfg.resolved_head_dim
            total += eff * per_tok * dtype_bytes * batch
        else:
            s = cfg.ssm
            total += (s.n_heads(cfg.d_model) * s.head_dim * s.d_state * 4
                      * batch)
        if cfg.is_cross_layer(i) or cfg.is_encdec:
            total += (cfg.n_frontend_tokens * 2 * cfg.n_kv_heads
                      * cfg.resolved_head_dim * dtype_bytes * batch)
    return total


def weight_stream_bytes(cfg: ModelConfig, n_params: float) -> float:
    """Bytes to stream `n_params` weights through HBM once. bf16 models
    stream 2 B/param; a weight-only-int8 model (cfg.quant, DESIGN.md
    §2.9) streams 1 B/param plus the per-output-channel f32 scales —
    one f32 per d_model-long input column, i.e. ~4/d_model extra bytes
    per param, accounted but negligible. The KV-cache side of the dtype
    story lives in `_kv_cache_bytes` (cfg.kv_dtype quantizes cached
    *activations*; cfg.quant quantizes *weights* — orthogonal knobs)."""
    if getattr(cfg, "quant", "") == "int8":
        return n_params * (1.0 + 4.0 / max(cfg.d_model, 1))
    return n_params * 2.0


@dataclass
class Estimate:
    flops: float            # global, one step
    hbm_bytes: float        # global, one step


def estimate(cfg: ModelConfig, shape_name: str, step: str,
             n_active_params: int, n_total_params: int,
             gamma: int = 16) -> Estimate:
    ishape = INPUT_SHAPES[shape_name]
    B, S = ishape.global_batch, ishape.seq_len
    P_act, P_tot = float(n_active_params), float(n_total_params)

    if step == "train":
        tokens = B * S
        fwd = _per_token_matmul_flops(cfg) * tokens \
            + B * _attn_context_flops(cfg, S, S, causal=True)
        flops = 4 * fwd            # fwd + bwd(2x) + remat recompute(1x)
        # params read fwd+bwd + grad write + optimizer touch; activations
        # at checkpoint boundaries r/w
        act = tokens * cfg.d_model * cfg.n_layers * 2 * 4.0
        hbm = P_tot * 2 * 4 + act
    elif step == "prefill":
        tokens = B * S
        flops = _per_token_matmul_flops(cfg) * tokens \
            + B * _attn_context_flops(cfg, S, S, causal=True)
        hbm = weight_stream_bytes(cfg, P_act) + _kv_cache_bytes(cfg, S, B) \
            + tokens * cfg.d_model * cfg.n_layers * 2 * 2.0
        # weights stream once more per microbatch
        hbm += weight_stream_bytes(cfg, P_act)
    else:  # decode / verify: q_tokens per request
        q = gamma if step == "verify" else 1
        tokens = B * q
        flops = _per_token_matmul_flops(cfg) * tokens \
            + B * _attn_context_flops(cfg, q, S, causal=False)
        hbm = weight_stream_bytes(cfg, P_act) + _kv_cache_bytes(cfg, S, B) \
            + tokens * cfg.d_model * cfg.n_layers * 2 * 2.0
    return Estimate(flops=flops, hbm_bytes=hbm)
