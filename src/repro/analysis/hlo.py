"""Loop-aware HLO analysis.

XLA's HloCostAnalysis (and naive text scans) count a while-loop body ONCE,
but scan-over-layers executes it `trip_count` times — so collectives (and
flops) inside the layer scan are under-counted by ~n_layers. This module
parses the partitioned HLO text, builds the computation call graph, reads
`known_trip_count` off every while op, and propagates multipliers from
ENTRY, yielding trip-corrected collective byte totals.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(
    r"(f64|f32|f16|bf16|s64|u64|s32|u32|s16|u16|s8|u8|pred|f8e4m3fn|f8e5m2)"
    r"\[([0-9,]*)\]")
_COMP_START = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(")
_CALLSITE = re.compile(r"(?:body|to_apply|calls)=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        size = 1
        for d in dims.split(","):
            if d:
                size *= int(d)
        total += size * DTYPE_BYTES[dt]
    return total


def parse_computations(hlo: str) -> Dict[str, List[str]]:
    """Computation headers sit at column 0 and end with '{'; instructions
    are indented; '}' at column 0 closes the computation."""
    comps: Dict[str, List[str]] = {}
    cur = None
    entry = None
    for raw in hlo.splitlines():
        if not raw:
            continue
        if raw[0] not in " \t":
            line = raw.strip()
            if line.endswith("{"):
                m = _COMP_START.match(line)
                if m:
                    cur = m.group(2)
                    comps[cur] = []
                    if m.group(1):
                        entry = cur
                continue
            if line == "}":
                cur = None
            continue
        if cur is not None:
            comps[cur].append(raw.strip())
    comps["__entry__"] = [entry]  # type: ignore
    return comps


def collective_bytes_corrected(hlo: str) -> Tuple[Dict[str, float],
                                                  Dict[str, int]]:
    """Trip-count-corrected {collective: bytes} and {collective: count},
    summing RESULT-shape bytes of each collective times the product of
    enclosing while trip counts."""
    comps = parse_computations(hlo)
    entry = comps.pop("__entry__")[0]

    # per-computation direct collectives and call edges
    direct: Dict[str, List[Tuple[str, int]]] = defaultdict(list)
    callers: Dict[str, List[Tuple[str, int]]] = defaultdict(list)
    for name, lines in comps.items():
        for line in lines:
            rhs = line.split("=", 1)[1] if "=" in line else line
            is_coll = None
            for c in COLLECTIVES:
                if re.search(rf"\b{c}(-start)?\(", rhs):
                    is_coll = c
                    break
            if is_coll:
                head = rhs.split(is_coll)[0]
                direct[name].append((is_coll, _shape_bytes(head)))
                continue
            trip = 1
            tm = _TRIP.search(line)
            if tm:
                trip = int(tm.group(1))
            for kw, mult in (("body", trip), ("condition", trip),
                             ("to_apply", 1), ("calls", 1)):
                for callee in re.findall(rf"{kw}=%?([\w.\-]+)", line):
                    callers[callee].append((name, mult))
            bm = _BRANCHES.search(line)
            if bm:
                for callee in re.findall(r"%?([\w.\-]+)", bm.group(1)):
                    callers[callee].append((name, 1))

    # invocation multiplier per computation (HLO call graph is a DAG)
    memo: Dict[str, float] = {}

    def mult_of(c: str) -> float:
        if c == entry:
            return 1.0
        if c in memo:
            return memo[c]
        memo[c] = 0.0  # cycle guard (shouldn't happen)
        memo[c] = sum(mult_of(p) * m for p, m in callers.get(c, [])) or 1.0
        return memo[c]

    out = {c: 0.0 for c in COLLECTIVES}
    counts = {c: 0 for c in COLLECTIVES}
    for name, colls in direct.items():
        for c, nbytes in colls:
            out[c] += nbytes * max(mult_of(name), 1.0)
            counts[c] += 1
    return out, counts
