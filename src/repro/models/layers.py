"""Common transformer building blocks (pure-JAX pytree params, no flax)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models.quantize import qdot


def dense_init(key, shape, scale=None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


# ---------------- norms ----------------

def norm_params(cfg: ModelConfig, d: int):
    if cfg.norm_type == "layer":
        return {"scale": jnp.ones((d,)), "bias": jnp.zeros((d,))}
    return {"scale": jnp.ones((d,))}


def apply_norm(p, x, cfg: ModelConfig):
    dt = x.dtype
    x = x.astype(jnp.float32)
    if cfg.norm_type == "layer":
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.var(x, axis=-1, keepdims=True)
        y = (x - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"] + p["bias"]
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
        y = x * jax.lax.rsqrt(ms + cfg.norm_eps) * p["scale"]
    return y.astype(dt)


def rms_norm_headwise(scale, x, eps=1e-6):
    """qk-norm: RMS norm over the last (head) dim."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(ms + eps) * scale).astype(dt)


# ---------------- rotary embeddings ----------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., T, H, D) or (..., T, D); positions: broadcastable to (..., T)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., T, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if x.ndim == positions.ndim + 2:                   # head axis present
        cos, sin = cos[..., None, :], sin[..., None, :]
    x1, x2 = x[..., : d // 2], x[..., d // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------- MLPs ----------------

def mlp_params(key, cfg: ModelConfig, d_model: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.mlp_type == "gelu":
        return {
            "wi": dense_init(k1, (d_model, d_ff)),
            "bi": jnp.zeros((d_ff,)),
            "wo": dense_init(k2, (d_ff, d_model)),
            "bo": jnp.zeros((d_model,)),
        }
    return {  # swiglu
        "wg": dense_init(k1, (d_model, d_ff)),
        "wu": dense_init(k2, (d_model, d_ff)),
        "wd": dense_init(k3, (d_ff, d_model)),
    }


def apply_mlp(p, x, cfg: ModelConfig):
    # matmuls dispatch through qdot so the same step functions run
    # weight-only-int8 params (models/quantize.py) unchanged
    if cfg.mlp_type == "gelu":
        h = jax.nn.gelu(qdot(x, p["wi"]) + p["bi"])
        return qdot(h, p["wo"]) + p["bo"]
    return qdot(jax.nn.silu(qdot(x, p["wg"])) * qdot(x, p["wu"]), p["wd"])
