"""Mixture-of-experts: shared + routed top-k experts.

Implementation: capacity-bounded sort-based dispatch -> per-expert dense
einsum (E, C, d) x (E, d, f). HLO FLOPs are proportional to *active*
compute (N * top_k * capacity_factor), so roofline bookkeeping stays
honest, and everything is differentiable (gather/scatter + einsum) so the
same path serves train_step and serve_step. `dense_moe_reference` is the
FLOP-inflated but trivially-correct oracle used by tests.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.config import ModelConfig, MoEConfig
from repro.models.layers import dense_init, mlp_params, apply_mlp


def moe_params(key, cfg: ModelConfig, moe: MoEConfig):
    d, E, f = cfg.d_model, moe.n_routed, moe.d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, E), scale=0.02),
        "w_gate": dense_init(ks[1], (E, d, f)),
        "w_up": dense_init(ks[2], (E, d, f)),
        "w_down": dense_init(ks[3], (E, f, d)),
    }
    if moe.n_shared:
        p["shared"] = mlp_params(ks[4], cfg, d, moe.shared_width)
    return p


def route_topk(logits, top_k):
    """Softmax router with renormalized top-k weights.

    (DeepSeek-V3 uses sigmoid+bias routing; we use the softmax formulation
    common to Qwen-MoE/Jamba — noted adaptation in DESIGN.md.)
    Returns (weights (N,k) f32, idx (N,k) i32, probs (N,E) f32).
    """
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, idx = jax.lax.top_k(probs, top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    return w, idx, probs


def apply_moe(p, x, cfg: ModelConfig, moe: MoEConfig):
    """x: (..., d). Returns (out, aux_loss).

    Exact (no token dropping): token copies are sorted by expert and run
    through `lax.ragged_dot` grouped matmuls, so compiled FLOPs equal the
    active compute N * top_k * (3 * d * f) and serving stays lossless.
    """
    orig_shape = x.shape
    d = orig_shape[-1]
    flat = x.reshape(-1, d)
    N = flat.shape[0]
    E, k = moe.n_routed, moe.top_k

    w, idx, probs = route_topk(flat @ p["router"], k)

    # ---- sort token copies by expert ----
    expert_of_copy = idx.reshape(-1)                        # (N*k,)
    order = jnp.argsort(expert_of_copy, stable=True)
    token_of_copy = (jnp.arange(N * k) // k)[order]
    weight_of_copy = w.reshape(-1)[order]
    group_sizes = jnp.bincount(expert_of_copy, length=E)    # (E,)

    xs = flat[token_of_copy]                                # (N*k, d)
    if cfg.moe_dispatch == "gather_tokens":
        # replicate the (small) token rows so expert weights stay put;
        # GSPMD inserts token all-gather + output reduce-scatter instead
        # of gathering the expert weights (§Perf H2)
        from jax.sharding import PartitionSpec as _P
        xs = jax.lax.with_sharding_constraint(xs, _P(None, None))
        group_sizes = jax.lax.with_sharding_constraint(group_sizes, _P(None))
    h = jax.nn.silu(jax.lax.ragged_dot(xs, p["w_gate"], group_sizes))
    h = h * jax.lax.ragged_dot(xs, p["w_up"], group_sizes)
    y = jax.lax.ragged_dot(h, p["w_down"], group_sizes)     # (N*k, d)

    y = y * weight_of_copy.astype(y.dtype)[:, None]
    out = jnp.zeros((N, d), flat.dtype).at[token_of_copy].add(y)

    if moe.n_shared:
        out = out + apply_mlp(p["shared"], flat, cfg)

    # Switch-style load-balance auxiliary loss: E * sum_e f_e * P_e
    frac_tokens = jnp.mean(
        (jax.nn.one_hot(idx, E, dtype=jnp.float32)).sum(1), axis=0)  # (E,)
    mean_prob = probs.mean(0)
    aux = E * jnp.sum(frac_tokens / k * mean_prob)

    return out.reshape(orig_shape), aux


def dense_moe_reference(p, x, cfg: ModelConfig, moe: MoEConfig):
    """O(N*E) oracle: every token through every expert, top-k weighted."""
    orig_shape = x.shape
    flat = x.reshape(-1, orig_shape[-1])
    N = flat.shape[0]
    E, k = moe.n_routed, moe.top_k
    w, idx, _ = route_topk(flat @ p["router"], k)
    wfull = jnp.zeros((N, E), jnp.float32)
    wfull = wfull.at[jnp.arange(N)[:, None], idx].set(w)
    h = jax.nn.silu(jnp.einsum("nd,edf->nef", flat, p["w_gate"]))
    h = h * jnp.einsum("nd,edf->nef", flat, p["w_up"])
    y = jnp.einsum("nef,efd->ned", h, p["w_down"])
    out = jnp.einsum("ned,ne->nd", y.astype(jnp.float32), wfull).astype(flat.dtype)
    if moe.n_shared:
        out = out + apply_mlp(p["shared"], flat, cfg)
    return out.reshape(orig_shape)
