"""Mamba2 SSD (state-space duality) mixer.

Full-sequence path uses the chunked SSD algorithm (intra-chunk dual
"attention" form + inter-chunk state recurrence via lax.scan); decode path
is the O(1) recurrent state update. `ssd_reference` (naive recurrence over
time) is the oracle for tests, and `repro.kernels.ssd_scan` is the Pallas
TPU kernel for the intra-chunk compute.

Paged serving note (DESIGN.md §2.8): SSM state does NOT page. The
recurrent state (`ssm_state`) and conv tail (`conv_state`) are O(1) per
request — a fixed (d_state x head) block regardless of sequence length —
so there is nothing to page: the paged cache keeps them slot-indexed
exactly like the resident layout, and only attention/MLA KV (which grows
with the sequence) moves into the page pool. Hybrid models therefore mix
both regimes in one cache pytree.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, SSMConfig
from repro.models.attention import take_rows
from repro.models.layers import dense_init
from repro.models.quantize import qdot


# ---------------------------------------------------------------- params

def ssm_params(key, cfg: ModelConfig):
    s: SSMConfig = cfg.ssm
    D = cfg.d_model
    din = s.d_inner(D)
    H = s.n_heads(D)
    G, N = s.n_groups, s.d_state
    conv_dim = din + 2 * G * N
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], (D, 2 * din + 2 * G * N + H)),
        "conv_w": dense_init(ks[1], (s.d_conv, conv_dim), scale=0.2),
        "conv_b": jnp.zeros((conv_dim,)),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)),
        "D_skip": jnp.ones((H,)),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 0.01))),  # softplus^-1(0.01)
        "norm_scale": jnp.ones((din,)),
        "out_proj": dense_init(ks[2], (din, D)),
    }


def make_ssm_state(batch, cfg: ModelConfig, dtype=jnp.float32):
    s = cfg.ssm
    D = cfg.d_model
    H, P, N = s.n_heads(D), s.head_dim, s.d_state
    conv_dim = s.d_inner(D) + 2 * s.n_groups * N
    return {
        "ssm": jnp.zeros((batch, H, P, N), dtype),
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }


# ------------------------------------------------------------- SSD core

def ssd_chunked(x, dt, A, B, C, chunk, initial_state=None):
    """Chunked SSD scan.

    x:  (b, L, H, P) inputs (already dt-weighted? no: raw)
    dt: (b, L, H)    positive step sizes
    A:  (H,)         negative decay rates
    B:  (b, L, G, N) input projections
    C:  (b, L, G, N) output projections
    Returns (y (b, L, H, P), final_state (b, H, P, N)).
    """
    b, L, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    chunk = min(chunk, L)          # decode (L=1) degenerates to the recurrence
    pad = (-L) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Lp = L + pad
    nc = Lp // chunk
    rep = H // G  # heads per group

    xc = x.reshape(b, nc, chunk, H, P)
    dtc = dt.reshape(b, nc, chunk, H).astype(jnp.float32)
    Bc = B.reshape(b, nc, chunk, G, N).astype(jnp.float32)
    Cc = C.reshape(b, nc, chunk, G, N).astype(jnp.float32)
    # broadcast groups to heads
    Bh = jnp.repeat(Bc, rep, axis=3)           # (b,nc,Q,H,N)
    Ch = jnp.repeat(Cc, rep, axis=3)

    dA = dtc * A                                # (b,nc,Q,H) negative
    dA_cum = jnp.cumsum(dA, axis=2)             # within-chunk cumulative decay

    # ---- intra-chunk (dual attention form) ----
    # L_mat[i,j] = exp(dA_cum[i] - dA_cum[j]) for i >= j else 0
    seg = dA_cum[:, :, :, None, :] - dA_cum[:, :, None, :, :]   # (b,nc,Q,Q,H)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    Lmat = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bcihn,bcjhn->bcijh", Ch, Bh) * Lmat    # (b,nc,Q,Q,H)
    xdt = xc.astype(jnp.float32) * dtc[..., None]               # (b,nc,Q,H,P)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, xdt)

    # ---- chunk states ----
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)       # (b,nc,Q,H)
    state_c = jnp.einsum("bcqhn,bcqh,bcqhp->bchpn", Bh, decay_to_end * dtc, xc.astype(jnp.float32))

    # ---- inter-chunk recurrence ----
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])                  # (b,nc,H)
    s0 = (jnp.zeros((b, H, P, N), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def body(s_prev, inp):
        dec, sc = inp                                           # (b,H), (b,H,P,N)
        s_new = s_prev * dec[:, :, None, None] + sc
        return s_new, s_prev

    xs = (chunk_decay.swapaxes(0, 1), state_c.swapaxes(0, 1))
    s_final, s_before = jax.lax.scan(body, s0, xs)
    s_before = s_before.swapaxes(0, 1)                          # (b,nc,H,P,N)

    # ---- inter-chunk contribution ----
    in_decay = jnp.exp(dA_cum)                                  # (b,nc,Q,H)
    y_inter = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp", Ch, s_before, in_decay)

    y = (y_intra + y_inter).reshape(b, Lp, H, P)[:, :L]
    return y.astype(x.dtype), s_final


def ssd_reference(x, dt, A, B, C, initial_state=None):
    """Naive O(L) recurrence oracle: h_t = exp(dt A) h + dt B x; y = C h."""
    b, L, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=2).astype(jnp.float32)
    Ch = jnp.repeat(C, rep, axis=2).astype(jnp.float32)
    s = (jnp.zeros((b, H, P, N), jnp.float32) if initial_state is None
         else initial_state.astype(jnp.float32))

    def body(s, inp):
        xt, dtt, Bt, Ct = inp                 # (b,H,P),(b,H),(b,H,N),(b,H,N)
        dec = jnp.exp(dtt * A)                # (b,H)
        s = s * dec[:, :, None, None] + jnp.einsum(
            "bhn,bh,bhp->bhpn", Bt, dtt, xt.astype(jnp.float32))
        y = jnp.einsum("bhn,bhpn->bhp", Ct, s)
        return s, y

    xs = (x.swapaxes(0, 1), dt.astype(jnp.float32).swapaxes(0, 1),
          Bh.swapaxes(0, 1), Ch.swapaxes(0, 1))
    s, ys = jax.lax.scan(body, s, xs)
    return ys.swapaxes(0, 1).astype(x.dtype), s


# ------------------------------------------------------------ mixer apply

def _causal_conv(xbc, w, bias):
    """Depthwise causal conv along time. xbc: (B, L, C), w: (K, C)."""
    K = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i: i + xbc.shape[1], :] * w[i] for i in range(K))
    return out + bias


def _split_in_proj(z_xbc_dt, cfg: ModelConfig):
    s = cfg.ssm
    D = cfg.d_model
    din = s.d_inner(D)
    GN = s.n_groups * s.d_state
    H = s.n_heads(D)
    z = z_xbc_dt[..., :din]
    xbc = z_xbc_dt[..., din: 2 * din + 2 * GN]
    dt = z_xbc_dt[..., 2 * din + 2 * GN:]
    assert dt.shape[-1] == H
    return z, xbc, dt


def _gated_rmsnorm(y, z, scale, eps):
    dt_ = y.dtype
    y = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(y * y, axis=-1, keepdims=True)
    return (y * jax.lax.rsqrt(ms + eps) * scale).astype(dt_)


def ssm_mixer(p, cfg: ModelConfig, x, state=None, use_kernel: bool = False,
              slot_idx=None, write=True, token_mask=None):
    """Full-sequence (state=None or carried) SSD mixer.

    x: (B, L, d_model). Returns (out, new_state or None).

    slot_idx: (B,) — `state` is a resident slot pool (batch axis larger
    than B); row b of x advances pool slot slot_idx[b]. Reads gather the
    B active rows; the returned new_state is then a sub-sized *write
    delta* the caller scatters into the pool at the top of the jitted
    step. write=False scores without committing the recurrent state
    (returns new_state=None).

    token_mask: (B, L) bool — real tokens True, *suffix* shape padding
    False (chunked prefill's pad-and-mask final chunk). Masked tokens
    get dt = 0, so the recurrence passes the state through them
    unchanged (exp(0) decay, zero input); the carried conv history is
    gathered at each row's real-token count so it holds the last real
    tokens, not the padding.
    """
    s = cfg.ssm
    D = cfg.d_model
    din, H, P = s.d_inner(D), s.n_heads(D), s.head_dim
    G, N = s.n_groups, s.d_state
    B_, L, _ = x.shape

    st = take_rows(state, slot_idx) if state is not None else None
    if token_mask is not None:
        assert st is not None, "token_mask requires a carried state"

    z, xbc, dt = _split_in_proj(qdot(x, p["in_proj"]), cfg)
    if st is not None:
        # prepend conv history
        hist = st["conv"].astype(xbc.dtype)
        xbc_ext = jnp.concatenate([hist, xbc], axis=1)
        conv_out = _causal_conv(xbc_ext, p["conv_w"], p["conv_b"])[:, hist.shape[1]:]
        if token_mask is None or s.d_conv <= 1:
            new_conv = xbc_ext[:, -(s.d_conv - 1):, :] if s.d_conv > 1 else hist
        else:
            # the last d_conv-1 *real* rows: real tokens are a prefix, so
            # row b's window ends at hist_len + n_valid[b] in xbc_ext
            n_valid = token_mask.sum(-1).astype(jnp.int32)          # (B,)
            idx = n_valid[:, None] + jnp.arange(s.d_conv - 1)       # (B, K-1)
            new_conv = jnp.take_along_axis(xbc_ext, idx[:, :, None], axis=1)
    else:
        conv_out = _causal_conv(xbc, p["conv_w"], p["conv_b"])
        new_conv = None
    xbc = jax.nn.silu(conv_out)

    xs = xbc[..., :din].reshape(B_, L, H, P)
    Bmat = xbc[..., din: din + G * N].reshape(B_, L, G, N)
    Cmat = xbc[..., din + G * N:].reshape(B_, L, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    if token_mask is not None:
        # dt = 0 makes a masked token a no-op in the recurrence: decay
        # exp(0 * A) = 1 and input weight dt * B x = 0
        dt = jnp.where(token_mask[:, :, None], dt, 0.0)
    A = -jnp.exp(p["A_log"])

    init = st["ssm"] if st is not None else None
    if use_kernel:
        from repro.kernels.ssd_scan import ops as ssd_ops
        y, s_final = ssd_ops.ssd(xs, dt, A, Bmat, Cmat, s.chunk_size, init)
    else:
        y, s_final = ssd_chunked(xs, dt, A, Bmat, Cmat, s.chunk_size, init)
    y = y + p["D_skip"][:, None] * xs
    y = y.reshape(B_, L, din)
    y = _gated_rmsnorm(y, z, p["norm_scale"], cfg.norm_eps)
    out = qdot(y, p["out_proj"])

    new_state = None
    if state is not None and write:
        adv = (L if token_mask is None
               else token_mask.sum(-1).astype(jnp.int32))
        new_state = {"ssm": (s_final if slot_idx is None
                             else s_final.astype(state["ssm"].dtype)),
                     "conv": new_conv.astype(state["conv"].dtype),
                     "pos": st["pos"] + adv}
    return out, new_state
