"""Attention: GQA (full / sliding-window / qk-norm / QKV-bias), MLA
(DeepSeek latent), and cross-attention — all built on one blocked
online-softmax primitive that never materializes a (T, S) score matrix
larger than (T, block). This is the dry-run-safe XLA path; the Pallas
kernels in `repro.kernels` are the TPU fast path with identical semantics.

KV caches are dicts of arrays (pytrees):
  {"k": (B, C, Hkv, Dk), "v": (B, C, Hkv, Dv), "slot_pos": (B, C) int32}
`slot_pos` holds the absolute position stored in each slot (-1 = empty).
Ring caches (sliding window) write at `pos % C`; masking is always done
against `slot_pos`, so eviction is correctness-preserving as long as
C >= window + max_segment (we allocate window + 128).

Paged pools (DESIGN.md §2.8) use the same leaf names but a page axis:
  {"k": (P, ps, Hkv, Dk), "v": (P, ps, Hkv, Dv), "slot_pos": (P, ps)}
where P = number of physical pages and ps = tokens per page. A request
owns an ordered list of pages (its block table); `take_rows` with a
`page_view` (B, n_view) int32 table gathers the view into exactly the
resident layout above with C = n_view * ps, so every attention routine
below runs unchanged on paged caches. Unmapped view entries point at a
reserved NULL page whose slot_pos stays -1 (masked like any empty slot).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.config import ModelConfig, MLAConfig
from repro.models.layers import apply_rope, dense_init, rms_norm_headwise
from repro.models.quantize import qdot

NEG_INF = -1e30
RING_MARGIN = 128  # extra ring slots beyond the window (max verify segment)


# =====================================================================
# blocked online-softmax attention primitive
# =====================================================================

def _merge_partials(a, b):
    """Merge two online-softmax partial states (m, l, acc)."""
    m_a, l_a, acc_a = a
    m_b, l_b, acc_b = b
    m = jnp.maximum(m_a, m_b)
    ea = jnp.exp(m_a - m)
    eb = jnp.exp(m_b - m)
    l = l_a * ea + l_b * eb
    acc = acc_a * ea[..., None] + acc_b * eb[..., None]
    return m, l, acc


def attend_partial(q, k, v, q_pos, k_pos, *, scale, causal=True, window=0,
                   extra_mask=None, block=1024):
    """Blocked attention returning online-softmax partials.

    q: (B, T, Hkv, G, Dk)   (GQA groups folded into q)
    k: (B, S, Hkv, Dk); v: (B, S, Hkv, Dv)
    q_pos: (B, T) absolute positions; k_pos: (B, S) slot positions (-1 empty)
    extra_mask: optional (B, T, S) bool, ANDed in (tree masks).
    Returns (m, l, acc): (B,T,Hkv,G), (B,T,Hkv,G), (B,T,Hkv,G,Dv).
    """
    B, T, Hkv, G, Dk = q.shape
    S = k.shape[1]
    Dv = v.shape[-1]
    qf = q.astype(jnp.float32)

    block = min(block, S)
    pad = (-S) % block
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
        if extra_mask is not None:
            extra_mask = jnp.pad(extra_mask, ((0, 0), (0, 0), (0, pad)))
    nb = (S + pad) // block

    # scan over KV blocks: xs leading dim = nb
    k_b = k.reshape(B, nb, block, Hkv, Dk).swapaxes(0, 1)
    v_b = v.reshape(B, nb, block, Hkv, Dv).swapaxes(0, 1)
    kp_b = k_pos.reshape(B, nb, block).swapaxes(0, 1)
    xs = (k_b, v_b, kp_b)
    if extra_mask is not None:
        em_b = extra_mask.reshape(B, T, nb, block).transpose(2, 0, 1, 3)
        xs = xs + (em_b,)

    m0 = jnp.full((B, T, Hkv, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, T, Hkv, G), jnp.float32)
    acc0 = jnp.zeros((B, T, Hkv, G, Dv), jnp.float32)

    def body(carry, x):
        if extra_mask is not None:
            kc, vc, kpc, emc = x
        else:
            kc, vc, kpc = x
            emc = None
        m, l, acc = carry
        # scores: (B, T, Hkv, G, block)
        s = jnp.einsum("bthgd,bshd->bthgs", qf, kc.astype(jnp.float32)) * scale
        valid = kpc[:, None, :] >= 0                                 # (B,1,block)
        if causal:
            valid = valid & (kpc[:, None, :] <= q_pos[:, :, None])   # (B,T,block)
        if window:
            valid = valid & (q_pos[:, :, None] - kpc[:, None, :] < window)
        if emc is not None:
            valid = valid & emc
        s = jnp.where(valid[:, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        # zero out fully-masked rows (exp(NEG_INF - NEG_INF) = 1 otherwise)
        p = jnp.where(valid[:, :, None, None, :], p, 0.0)
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bthgs,bshd->bthgd", p, vc.astype(jnp.float32))
        return (m_new, l, acc), None

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), xs)
    return m, l, acc


def attend_partial_parallel(q, k, v, q_pos, k_pos, *, scale, causal=True,
                            window=0, extra_mask=None, block=1024):
    """Parallel-partial (flash-decoding style) attention for SMALL T.

    Unlike `attend_partial` (sequential lax.scan carry), every KV block's
    partial softmax is computed independently and merged with a tree
    reduction over the block axis. With the KV cache sharded along its
    capacity dim, GSPMD turns the merge into a psum of tiny (B,T,H,G,Dv)
    partials instead of all-gathering the cache — the §Perf seq-parallel
    KV optimization. Numerics identical to attend_partial.
    """
    B, T, Hkv, G, Dk = q.shape
    S = k.shape[1]
    Dv = v.shape[-1]
    qf = q.astype(jnp.float32)

    block = min(block, S)
    pad = (-S) % block
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
        if extra_mask is not None:
            extra_mask = jnp.pad(extra_mask, ((0, 0), (0, 0), (0, pad)))
    nb = (S + pad) // block

    kb = k.reshape(B, nb, block, Hkv, Dk)
    vb = v.reshape(B, nb, block, Hkv, Dv)
    kpb = k_pos.reshape(B, nb, block)

    # scores: (B, nb, T, Hkv, G, block)
    s = jnp.einsum("bthgd,bnshd->bnthgs", qf, kb.astype(jnp.float32)) * scale
    valid = (kpb >= 0)[:, :, None, :]
    if causal:
        valid = valid & (kpb[:, :, None, :] <= q_pos[:, None, :, None])
    if window:
        valid = valid & (q_pos[:, None, :, None] - kpb[:, :, None, :] < window)
    if extra_mask is not None:
        em = extra_mask.reshape(B, T, nb, block).transpose(0, 2, 1, 3)
        valid = valid & em
    s = jnp.where(valid[:, :, :, None, None, :], s, NEG_INF)

    m_n = s.max(axis=-1)                                  # (B,nb,T,Hkv,G)
    p = jnp.where(valid[:, :, :, None, None, :],
                  jnp.exp(s - m_n[..., None]), 0.0)
    l_n = p.sum(axis=-1)
    acc_n = jnp.einsum("bnthgs,bnshd->bnthgd", p, vb.astype(jnp.float32))

    m = m_n.max(axis=1)                                   # (B,T,Hkv,G)
    w = jnp.exp(m_n - m[:, None])
    l = (l_n * w).sum(axis=1)
    acc = (acc_n * w[..., None]).sum(axis=1)
    return m, l, acc


def finalize_partial(partial, out_dtype):
    m, l, acc = partial
    l = jnp.where(l == 0.0, 1.0, l)
    return (acc / l[..., None]).astype(out_dtype)


def blocked_attention(q, k, v, q_pos, k_pos, *, scale, causal=True, window=0,
                      extra_mask=None, block=1024, segment=None,
                      parallel=False):
    """Full attention = history partial (k, v) merged with an optional
    `segment` = (k_seg, v_seg, pos_seg, mask_seg) for freshly-drafted tokens
    (tree verification), then normalized. `parallel=True` uses the
    flash-decoding parallel-partial path (small T only)."""
    attend = attend_partial_parallel if parallel else attend_partial
    partial = attend(q, k, v, q_pos, k_pos, scale=scale, causal=causal,
                     window=window, extra_mask=extra_mask, block=block)
    if segment is not None:
        k_s, v_s, pos_s, mask_s = segment
        p2 = attend_partial(q, k_s, v_s, q_pos, pos_s, scale=scale,
                            causal=causal, window=window, extra_mask=mask_s,
                            block=max(k_s.shape[1], 1))
        partial = _merge_partials(partial, p2)
    return finalize_partial(partial, q.dtype)


# =====================================================================
# KV cache helpers
# =====================================================================

def make_kv_cache(batch, capacity, n_kv, dk, dv=None, dtype=jnp.bfloat16,
                  quantized=False):
    dv = dv or dk
    store = jnp.int8 if quantized else dtype
    c = {
        "k": jnp.zeros((batch, capacity, n_kv, dk), store),
        "v": jnp.zeros((batch, capacity, n_kv, dv), store),
        "slot_pos": jnp.full((batch, capacity), -1, jnp.int32),
    }
    if quantized:
        c["k_scale"] = jnp.zeros((batch, capacity, n_kv), jnp.float32)
        c["v_scale"] = jnp.zeros((batch, capacity, n_kv), jnp.float32)
    return c


def make_paged_kv_cache(n_pages, page_size, n_kv, dk, dv=None,
                        dtype=jnp.bfloat16, quantized=False):
    """Physical page pool for one attention sub-layer (DESIGN.md §2.8).

    Same leaves as `make_kv_cache` but laid out per page:
    (n_pages, page_size, ...). slot_pos starts at -1 everywhere so a page
    is invisible to reads until real rows are written into it.
    """
    dv = dv or dk
    store = jnp.int8 if quantized else dtype
    c = {
        "k": jnp.zeros((n_pages, page_size, n_kv, dk), store),
        "v": jnp.zeros((n_pages, page_size, n_kv, dv), store),
        "slot_pos": jnp.full((n_pages, page_size), -1, jnp.int32),
    }
    if quantized:
        c["k_scale"] = jnp.zeros((n_pages, page_size, n_kv), jnp.float32)
        c["v_scale"] = jnp.zeros((n_pages, page_size, n_kv), jnp.float32)
    return c


def make_paged_mla_cache(n_pages, page_size, cfg: ModelConfig,
                         dtype=jnp.bfloat16):
    """Paged variant of `make_mla_cache` (latent KV pages)."""
    m = cfg.mla
    return {
        "k": jnp.zeros((n_pages, page_size, 1,
                        m.kv_lora_rank + m.qk_rope_head_dim), dtype),
        "v": jnp.zeros((n_pages, page_size, 1, m.kv_lora_rank), dtype),
        "slot_pos": jnp.full((n_pages, page_size), -1, jnp.int32),
    }


def _quantize(x):
    """Symmetric per-(token, head) int8 quantization. x: (B,T,H,D)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_cache(cache):
    """Materialize bf16 K/V views from an int8 cache (block-local on TPU;
    whole-array on the XLA reference path)."""
    if "k_scale" not in cache:
        return cache["k"], cache["v"]
    k = cache["k"].astype(jnp.float32) * cache["k_scale"][..., None]
    v = cache["v"].astype(jnp.float32) * cache["v_scale"][..., None]
    return k.astype(jnp.bfloat16), v.astype(jnp.bfloat16)


def cache_capacity(cfg: ModelConfig, max_len: int, layer_window: int) -> int:
    if layer_window:
        return min(max_len, layer_window + RING_MARGIN)
    return max_len


def kv_rows(cache, k_new, v_new, positions):
    """New-token KV rows in storage form (quantize/cast as the cache
    does): {"k","v","slot_pos"[,"k_scale","v_scale"]} with leading (B, T).
    These are both what `write_kv` scatters locally and the *write delta*
    the slot-resident path defers to one top-level in-place scatter."""
    rows = {"slot_pos": positions}
    if "k_scale" in cache:
        rows["k"], rows["k_scale"] = _quantize(k_new)
        rows["v"], rows["v_scale"] = _quantize(v_new)
    else:
        rows["k"] = k_new.astype(cache["k"].dtype)
        rows["v"] = v_new.astype(cache["v"].dtype)
    return rows


def set_rows(cache, rows, positions):
    """Scatter `kv_rows` at slot = position % capacity (ring if capacity
    < pos)."""
    C = cache["slot_pos"].shape[1]
    slot = positions % C                                   # (B, T)
    bidx = jnp.arange(positions.shape[0])[:, None]
    out = dict(cache)
    for key, val in rows.items():
        out[key] = cache[key].at[bidx, slot].set(val)
    return out


def write_kv(cache, k_new, v_new, positions):
    """Scatter new KV at slot = position % capacity (ring if capacity < pos)."""
    return set_rows(cache, kv_rows(cache, k_new, v_new, positions), positions)


def take_rows(cache, slot_idx, page_view=None):
    """Gather the active rows of a resident or paged cache (read path).

    slot pool (page_view=None): slot-indexed gather of the B active rows;
    attention only ever *reads* the gathered rows, write deltas are
    scattered at the top of the jitted step, touching new tokens only.

    paged pool (page_view (B, n_view) int32): `cache` leaves have leading
    (n_pages, page_size); the gather assembles each request's mapped
    pages into a (B, n_view * page_size, ...) sub-cache — exactly the
    resident layout with capacity C = n_view * ps, so downstream
    attention is unchanged. Read traffic is ∝ pages actually held (the
    view), not pool capacity.
    """
    if page_view is not None:
        B, nv = page_view.shape
        ps = cache["slot_pos"].shape[-1]
        rows = (page_view[:, :, None] * ps
                + jnp.arange(ps, dtype=page_view.dtype)).reshape(B, nv * ps)
        out = {}
        for key, val in cache.items():
            flat = val.reshape((val.shape[0] * val.shape[1],) + val.shape[2:])
            out[key] = jnp.take(flat, rows, axis=0)
        return out
    if slot_idx is None:
        return cache
    return {k: jnp.take(v, slot_idx, axis=0) for k, v in cache.items()}


def _attend_cached(qg, k_new, v_new, cache, positions, *, scale, window,
                   block, seg_mask, slot_idx, write, par, token_mask=None,
                   page_view=None):
    """Shared cache-backed attention core for GQA and MLA.

    Gathers the active rows (slot pool or plain batch), optionally writes
    the new tokens' KV (locally — the slot path returns the rows as a
    write delta for the caller's top-level scatter), and attends either
    over the written cache (plain decode/extend) or over the unmodified
    history merged with the fresh segment (no-commit scoring / tree
    masks). Returns (out, new_cache | write-delta | None).

    token_mask: (B, T) bool — suffix shape-padding rows (False) are
    written with slot_pos = -1 at their real column slots: invisible to
    every read (masking is always against slot_pos) and overwritten by
    the next real tokens at those positions.

    page_view: (B, n_view) int32 — cache is a paged pool; the gathered
    view (capacity n_view * page_size) plays the role of the sub-cache
    and, like the slot path, writes come back as a delta scattered by
    the caller through the block table."""
    B, T = positions.shape
    k_pos = (positions if token_mask is None
             else jnp.where(token_mask, positions, -1))
    sub = take_rows(cache, slot_idx, page_view)
    new_sub, new_cache = None, None
    if write:
        rows = kv_rows(sub, k_new, v_new, k_pos)
        new_sub = set_rows(sub, rows, positions)
        deferred = slot_idx is not None or page_view is not None
        new_cache = rows if deferred else new_sub
    if not write or seg_mask is not None:
        # history (old cache, fully causal) + fresh segment
        mask_s = seg_mask
        if mask_s is None:
            mask_s = jnp.broadcast_to(jnp.tril(jnp.ones((T, T), bool)),
                                      (B, T, T))
        ck, cv = dequantize_cache(sub)
        out = blocked_attention(
            qg, ck, cv, positions, sub["slot_pos"],
            scale=scale, causal=True, window=window, block=block,
            segment=(k_new, v_new, k_pos, mask_s), parallel=par)
    else:
        ck, cv = dequantize_cache(new_sub)
        out = blocked_attention(
            qg, ck, cv, positions,
            new_sub["slot_pos"], scale=scale, causal=True,
            window=window, block=block, parallel=par)
    return out, new_cache


# =====================================================================
# GQA attention layer
# =====================================================================

def gqa_params(key, cfg: ModelConfig, cross: bool = False):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, hq * hd)),
        "wk": dense_init(ks[1], (d, hkv * hd)),
        "wv": dense_init(ks[2], (d, hkv * hd)),
        "wo": dense_init(ks[3], (hq * hd, d)),
    }
    if cfg.qkv_bias:
        p.update(bq=jnp.zeros((hq * hd,)), bk=jnp.zeros((hkv * hd,)),
                 bv=jnp.zeros((hkv * hd,)))
    if cfg.qk_norm:
        p.update(q_norm=jnp.ones((hd,)), k_norm=jnp.ones((hd,)))
    return p


def _project_qkv(p, cfg: ModelConfig, x, positions, rope: bool):
    B, T, _ = x.shape
    hd = cfg.resolved_head_dim
    # qdot: plain matmul for f32/bf16 params, fused dequant for the
    # weight-only-int8 drafter path (models/quantize.py)
    q = qdot(x, p["wq"])
    k = qdot(x, p["wk"])
    v = qdot(x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, T, cfg.n_heads, hd)
    k = k.reshape(B, T, cfg.n_kv_heads, hd)
    v = v.reshape(B, T, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm_headwise(p["q_norm"], q, cfg.norm_eps)
        k = rms_norm_headwise(p["k_norm"], k, cfg.norm_eps)
    if rope and cfg.pos_embed == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_attention(p, cfg: ModelConfig, x, positions, *, cache=None,
                  seg_mask=None, window=0, block=1024, slot_idx=None,
                  write=True, token_mask=None, page_view=None):
    """Self-attention for any mode.

    x: (B, T, d); positions: (B, T) absolute positions of these tokens.
    cache=None        -> self-contained (train/score): attends within x only.
    cache=dict        -> decode/verify/prefill-with-cache: new KV written to
                         cache; queries attend to cache + fresh segment.
    seg_mask: (B, T, T) extra mask among the fresh tokens (tree verification;
              entry [b,i,j] = may token i attend to token j).
    slot_idx: (B,) — cache is a resident slot pool; row b of x lives in
              pool slot slot_idx[b]. Reads gather the B active rows; the
              returned "cache" is then a *write delta* (`kv_rows`) for
              the caller to scatter in place at the top of the jitted
              step — compute here is bit-identical to running on a
              pre-gathered sub-cache.
    write=False       -> no-commit scoring: returns new_cache=None and
              fresh tokens attend via the segment merge.
    page_view: (B, n_view) — cache is a paged page pool (DESIGN.md §2.8);
              reads gather only the mapped pages, writes come back as a
              delta the caller scatters through the block table.
    Returns (out, new_cache | write-delta | None).
    """
    B, T, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    g = hq // hkv
    scale = hd ** -0.5
    q, k, v = _project_qkv(p, cfg, x, positions, rope=True)
    qg = q.reshape(B, T, hkv, g, hd)
    par = cfg.decode_attn == "parallel" and cache is not None and T <= 32
    if cache is not None and cfg.decode_block:
        block = cfg.decode_block

    if cache is None:
        out = blocked_attention(qg, k, v, positions, positions, scale=scale,
                                causal=True, window=window,
                                extra_mask=seg_mask, block=block)
        new_cache = None
    else:
        out, new_cache = _attend_cached(
            qg, k, v, cache, positions, scale=scale, window=window,
            block=block, seg_mask=seg_mask, slot_idx=slot_idx, write=write,
            par=par, token_mask=token_mask, page_view=page_view)
    out = out.reshape(B, T, hq * hd)
    return qdot(out, p["wo"]), new_cache


def cross_attention(p, cfg: ModelConfig, x, kv_src=None, cache=None,
                    block=1024, slot_idx=None, write=True):
    """Cross-attention to frontend/encoder states.

    kv_src: (B, S, d) encoder states (prefill: projects and caches K/V).
    cache:  {"k","v","slot_pos"} of projected cross KV (decode reuses).
    slot_idx: (B,) — cache is a resident slot pool; fresh projections are
    returned as a write delta (scattered in place by the caller at the
    top of the jitted step), decode reads gather the active rows.
    """
    B, T, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    g = hq // hkv
    q = qdot(x, p["wq"]).reshape(B, T, hq, hd)
    if cfg.qkv_bias:
        q = q + p["bq"].reshape(hq, hd)
    if kv_src is not None:
        S = kv_src.shape[1]
        k = qdot(kv_src, p["wk"]).reshape(B, S, hkv, hd)
        v = qdot(kv_src, p["wv"]).reshape(B, S, hkv, hd)
        if cfg.qkv_bias:
            k = k + p["bk"].reshape(hkv, hd)
            v = v + p["bv"].reshape(hkv, hd)
        slot_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        if slot_idx is not None and cache is not None:
            # write delta: fresh full-row projections for the active slots
            cache = {"k": k.astype(cache["k"].dtype),
                     "v": v.astype(cache["v"].dtype),
                     "slot_pos": slot_pos} if write else None
        else:
            cache = {"k": k, "v": v, "slot_pos": slot_pos}
        # fresh projections are the active rows — no gather needed
        kr, vr, spr = k, v, slot_pos
    else:
        sub = take_rows(cache, slot_idx)
        kr, vr, spr = sub["k"], sub["v"], sub["slot_pos"]
        if slot_idx is not None:
            cache = None                 # decode: nothing to write back
    qg = q.reshape(B, T, hkv, g, hd)
    qpos = jnp.zeros((B, T), jnp.int32)  # non-causal: positions unused
    out = blocked_attention(qg, kr, vr, qpos, spr, scale=hd ** -0.5,
                            causal=False, window=0, block=block)
    out = out.reshape(B, T, hq * hd)
    return qdot(out, p["wo"]), cache


# =====================================================================
# MLA (DeepSeek-V3 multi-head latent attention), absorbed formulation
# =====================================================================

def mla_params(key, cfg: ModelConfig):
    m: MLAConfig = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 8)
    return {
        "wdq": dense_init(ks[0], (d, m.q_lora_rank)),
        "q_norm": jnp.ones((m.q_lora_rank,)),
        "wuq": dense_init(ks[1], (m.q_lora_rank, H * m.qk_head_dim)),
        "wdkv": dense_init(ks[2], (d, m.kv_lora_rank)),
        "kv_norm": jnp.ones((m.kv_lora_rank,)),
        "wkr": dense_init(ks[3], (d, m.qk_rope_head_dim)),
        "wuk": dense_init(ks[4], (m.kv_lora_rank, H * m.qk_nope_head_dim)),
        "wuv": dense_init(ks[5], (m.kv_lora_rank, H * m.v_head_dim)),
        "wo": dense_init(ks[6], (H * m.v_head_dim, d)),
    }


def make_mla_cache(batch, capacity, cfg: ModelConfig, dtype=jnp.bfloat16):
    m = cfg.mla
    return {
        "k": jnp.zeros((batch, capacity, 1, m.kv_lora_rank + m.qk_rope_head_dim), dtype),
        "v": jnp.zeros((batch, capacity, 1, m.kv_lora_rank), dtype),
        "slot_pos": jnp.full((batch, capacity), -1, jnp.int32),
    }


def _rms(x, scale, eps):
    dt = x.dtype
    x = x.astype(jnp.float32)
    return (x * jax.lax.rsqrt(jnp.mean(x * x, -1, keepdims=True) + eps) * scale).astype(dt)


def mla_attention(p, cfg: ModelConfig, x, positions, *, cache=None,
                  seg_mask=None, window=0, block=1024, slot_idx=None,
                  write=True, token_mask=None, page_view=None):
    """Absorbed MLA: the cache holds only (c_kv ++ k_pe) per token; W_UK is
    absorbed into the query and W_UV applied to the attention output. This
    is single-latent-head attention (Hkv=1, G=H). slot_idx/write as in
    `gqa_attention` (in-place slot-pool writes / no-commit reads)."""
    m: MLAConfig = cfg.mla
    B, T, _ = x.shape
    H = cfg.n_heads
    scale = m.qk_head_dim ** -0.5

    cq = _rms(x @ p["wdq"], p["q_norm"], cfg.norm_eps)
    q = (cq @ p["wuq"]).reshape(B, T, H, m.qk_head_dim)
    q_nope, q_pe = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)
    # absorb W_UK: (B,T,H,nope) @ (R,H,nope) -> (B,T,H,R)
    wuk = p["wuk"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_abs = jnp.einsum("bthn,rhn->bthr", q_nope, wuk)
    q_eff = jnp.concatenate([q_abs, q_pe], axis=-1)        # (B,T,H,R+rope)
    qg = q_eff.reshape(B, T, 1, H, m.kv_lora_rank + m.qk_rope_head_dim)

    ckv = _rms(x @ p["wdkv"], p["kv_norm"], cfg.norm_eps)   # (B,T,R)
    kpe = apply_rope(x @ p["wkr"], positions, cfg.rope_theta)
    k_eff = jnp.concatenate([ckv, kpe], axis=-1)[:, :, None, :]  # (B,T,1,R+rope)
    v_eff = ckv[:, :, None, :]                                   # (B,T,1,R)

    par = cfg.decode_attn == "parallel" and cache is not None and T <= 32
    if cache is not None and cfg.decode_block:
        block = cfg.decode_block
    if cache is None:
        out_lat = blocked_attention(qg, k_eff, v_eff, positions, positions,
                                    scale=scale, causal=True, window=window,
                                    extra_mask=seg_mask, block=block)
        new_cache = None
    else:
        out_lat, new_cache = _attend_cached(
            qg, k_eff, v_eff, cache, positions, scale=scale, window=window,
            block=block, seg_mask=seg_mask, slot_idx=slot_idx, write=write,
            par=par, token_mask=token_mask, page_view=page_view)
    out_lat = out_lat.reshape(B, T, H, m.kv_lora_rank)
    wuv = p["wuv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    out = jnp.einsum("bthr,rhv->bthv", out_lat, wuv).reshape(B, T, H * m.v_head_dim)
    return out @ p["wo"], new_cache
