"""Weight-only int8 drafter quantization (calibrate-then-swap).

Per-output-channel symmetric quantization of the drafter's dense and
embedding weights: each quantized leaf is replaced by a small dict
``{"w8": int8, "scale": f32}`` where ``scale`` keeps the reduced axis
as a broadcast-ready size-1 dim (``absmax / 127`` over the input axis
for dense kernels, over ``d_model`` for the embedding table). Mixers
dispatch through :func:`qdot` so the *same* jitted step functions run
either representation — a quantized pytree is simply a different leaf
structure, which re-keys the jit cache automatically.

Losslessness is by construction (DESIGN.md §2.9): only drafter
*proposals* change; the target's greedy accept/correct walk is
untouched, so committed streams stay greedy-exact while acceptance
rate (and therefore speed) may move.

Calibration is data-free: symmetric absmax per channel from the
trained checkpoint (the TensorRT-Model-Optimizer calibrate-then-swap
pattern), applied at load via ``load_checkpoint(..., quantize="int8")``
or at engine construction from ``CoSineConfig.drafter_quant`` /
``ModelConfig.quant``.
"""
from __future__ import annotations

import jax.numpy as jnp

# dense 2-D kernels eligible for weight-only int8: attention/cross
# projections, MLP, and the SSM in/out projections. Everything else
# (norm scales, biases, conv kernels, A_log/dt/D vectors) stays f32 —
# they are O(d) and contribute nothing to the decode weight stream.
_DENSE_KEYS = frozenset({
    "wq", "wk", "wv", "wo", "wi", "wg", "wu", "wd", "in_proj", "out_proj",
})
# MLA's latent projections are consumed through reshaped einsums (no
# single ``x @ w`` site to dispatch), and MoE expert banks go through
# ``lax.ragged_dot`` which takes plain arrays only.
_MLA_KEYS = frozenset({"wdq", "wuq", "wdkv", "wkr", "wuk", "wuv"})


def is_quantized(leaf) -> bool:
    """True iff `leaf` is a quantized-weight dict (``{"w8", "scale"}``)."""
    return isinstance(leaf, dict) and "w8" in leaf and "scale" in leaf


def quantize_weight(w, axis: int = -2):
    """Symmetric per-channel int8 quantization of one weight array.

    `axis` is the reduced (input) axis: ``-2`` for dense ``(..., K, N)``
    kernels (scale per output channel, shape ``(..., 1, N)``), ``-1``
    for the embedding table ``(V, D)`` (scale per vocab row, shape
    ``(V, 1)`` — the same scales serve the row lookup and the tied
    logits head). Leading stacked-stage axes (the vmap'd ``reps`` dim)
    are carried through, so ``lax.scan`` slices ``w8`` and ``scale``
    per layer together.
    """
    w = jnp.asarray(w)
    absmax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=axis,
                     keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    w8 = jnp.clip(jnp.round(w.astype(jnp.float32) / scale), -127, 127)
    return {"w8": w8.astype(jnp.int8), "scale": scale.astype(jnp.float32)}


def dequantize_weight(q, dtype=jnp.float32):
    """Inverse of :func:`quantize_weight` (up to rounding)."""
    if not is_quantized(q):
        return jnp.asarray(q, dtype)
    return (q["w8"].astype(jnp.float32) * q["scale"]).astype(dtype)


def qdot(x, w):
    """``x @ w`` that accepts either a plain array or a quantized dict.

    The quantized form streams int8 weights and applies the per-output
    -channel scale after the reduction — ``(x @ w8) * scale`` — the
    in-register dequant contract the Pallas kernel
    (`kernels/int8_gemv`) implements for the decode hot path. int8 ->
    bf16/f32 casts are exact (|w8| <= 127), so the only quantization
    error is the rounding already baked into ``w8``.
    """
    if not is_quantized(w):
        return x @ w
    return (x @ w["w8"].astype(x.dtype)) * w["scale"].astype(x.dtype)


def embed_lookup(emb, tokens, dtype):
    """Embedding row gather for plain or quantized tables."""
    if not is_quantized(emb):
        return emb[tokens].astype(dtype)
    return (emb["w8"][tokens].astype(dtype)
            * emb["scale"][tokens].astype(dtype))


def tied_logits(emb, x):
    """``x @ embed.T`` for plain or quantized embedding tables.

    Per-vocab-row scales are per-*output*-channel of the tied head, so
    they apply after the reduction exactly like :func:`qdot`.
    """
    if not is_quantized(emb):
        return x @ emb.T.astype(x.dtype)
    return (x @ emb["w8"].T.astype(x.dtype)) * emb["scale"].T.astype(x.dtype)


def _quantize_sublayer(p: dict) -> dict:
    out = {}
    for k, v in p.items():
        if k in ("mixer", "cross", "ffn") and isinstance(v, dict):
            if any(m in v for m in _MLA_KEYS):
                raise ValueError(
                    "int8 drafter quantization does not support MLA "
                    "mixers (latent projections are einsum-consumed); "
                    "use a dense-attention or SSM drafter")
            if "router" in v:  # MoE ffn: ragged_dot needs plain arrays
                out[k] = v
                continue
            out[k] = {kk: (quantize_weight(vv)
                           if kk in _DENSE_KEYS and not is_quantized(vv)
                           else vv)
                      for kk, vv in v.items()}
        else:
            out[k] = v
    return out


def quantize_params(params: dict, cfg=None) -> dict:
    """Calibrate-and-swap: quantize a trained checkpoint's dense weights.

    Returns a new params pytree where every eligible dense kernel and
    the embedding table (plus the untied head, if present) are replaced
    by ``{"w8", "scale"}`` dicts; norms, biases, conv kernels and the
    training-only ``mtp``/``encoder`` subtrees pass through untouched.
    Idempotent: already-quantized leaves are left alone. `cfg` is
    accepted for symmetry with other model entry points (the walk is
    purely structural).
    """
    del cfg
    out = {}
    for k, v in params.items():
        if k == "embed":
            out[k] = v if is_quantized(v) else quantize_weight(v, axis=-1)
        elif k == "head":
            out[k] = v if is_quantized(v) else quantize_weight(v, axis=-2)
        elif k == "stages":
            out[k] = [tuple(_quantize_sublayer(sub_p) for sub_p in stage)
                      for stage in v]
        else:  # final_norm, pos, encoder, mtp, ...
            out[k] = v
    return out


def resolve_drafter_quant(drafters, pool_default: str = "none"):
    """Apply per-node quantization to engine drafter specs.

    `drafters` is the engine's ``(ModelConfig, params, domain)`` list.
    Each node's effective mode is ``cfg.quant`` when set, else the
    pool-wide ``CoSineConfig.drafter_quant`` default — so one pool can
    run an int8 node beside bf16 nodes. Returns new specs with the
    resolved mode stamped into each cfg (jits key on it statically) and
    params quantized where requested.
    """
    out = []
    for cfg, params, domain in drafters:
        eff = cfg.quant or pool_default
        if eff == "int8":
            cfg = cfg if cfg.quant == "int8" else \
                cfg.with_overrides(quant="int8")
            params = quantize_params(params, cfg)
        out.append((cfg, params, domain))
    return out
