"""Composable model assembly for all assigned architectures.

A model is described by `ModelConfig`; layers are grouped into *stages*
(maximal repeated patterns of per-layer specs) and each stage is executed
with `jax.lax.scan` over stacked parameters, so 61-layer models compile as
small HLO. One `apply()` serves train/score, prefill, decode and
speculative verification (chain or tree) — mode is determined by
(cache, seg_mask, write).

Params and caches are plain pytrees (nested dicts/tuples of jnp arrays).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import attention as attn
from repro.models import quantize
from repro.models import ssm as ssm_mod
from repro.models.layers import (apply_mlp, apply_norm, embed_init,
                                 mlp_params, norm_params)
from repro.models.moe import apply_moe, moe_params


# ====================================================== layer plan

@dataclass(frozen=True)
class LayerSpec:
    mixer: str          # "attn" | "mla" | "ssm"
    cross: bool         # has a cross-attention sub-block
    ffn: str            # "dense" | "moe" | "none"


def _spec_for(cfg: ModelConfig, idx: int) -> LayerSpec:
    kind = cfg.layer_kind(idx)
    if kind == "ssm":
        mixer = "ssm"
    elif cfg.attention == "mla":
        mixer = "mla"
    else:
        mixer = "attn"
    if cfg.family == "ssm":
        ffn = "none" if cfg.d_ff == 0 else "dense"
    elif cfg.is_moe_layer(idx):
        ffn = "moe"
    else:
        ffn = "dense"
    cross = cfg.is_cross_layer(idx) or cfg.is_encdec
    return LayerSpec(mixer=mixer, cross=cross, ffn=ffn)


def _compress(specs: list) -> list:
    """Greedy max-coverage run-length stage compression.

    Returns [(pattern tuple, repeats), ...] with sum(len(p)*r) == len(specs).
    """
    stages = []
    i = 0
    n = len(specs)
    while i < n:
        best_p, best_k = 1, 1
        for p in range(1, (n - i) // 2 + 1):
            k = 1
            while specs[i + k * p: i + (k + 1) * p] == specs[i: i + p]:
                k += 1
            if k > 1 and (p * k > best_p * best_k
                          or (p * k == best_p * best_k and p < best_p)):
                best_p, best_k = p, k
        if best_k == 1:  # no repetition: take the longest non-repeating run
            best_p = n - i
        stages.append((tuple(specs[i: i + best_p]), best_k))
        i += best_p * best_k
    return stages


def layer_plan(cfg: ModelConfig) -> list:
    return _compress([_spec_for(cfg, i) for i in range(cfg.n_layers)])


def effective_window(cfg: ModelConfig) -> int:
    if cfg.attention == "swa" and cfg.sliding_window:
        return cfg.sliding_window
    if cfg.long_context == "swa":
        return cfg.long_context_window
    return 0


# ====================================================== params

def _init_sublayer(key, spec: LayerSpec, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    p = {"ln1": norm_params(cfg, cfg.d_model)}
    if spec.mixer == "attn":
        p["mixer"] = attn.gqa_params(ks[0], cfg)
    elif spec.mixer == "mla":
        p["mixer"] = attn.mla_params(ks[0], cfg)
    else:
        p["mixer"] = ssm_mod.ssm_params(ks[0], cfg)
    if spec.cross:
        p["ln_cross"] = norm_params(cfg, cfg.d_model)
        p["cross"] = attn.gqa_params(ks[1], cfg)
    if spec.ffn != "none":
        p["ln2"] = norm_params(cfg, cfg.d_model)
        if spec.ffn == "moe":
            p["ffn"] = moe_params(ks[2], cfg, cfg.moe)
        else:
            p["ffn"] = mlp_params(ks[2], cfg, cfg.d_model, cfg.d_ff)
    return p


def _init_stage(key, pattern, repeats, cfg: ModelConfig):
    def init_one(k):
        kk = jax.random.split(k, len(pattern))
        return tuple(_init_sublayer(kk[j], pattern[j], cfg)
                     for j in range(len(pattern)))
    return jax.vmap(init_one)(jax.random.split(key, repeats))


def _init_encoder(key, cfg: ModelConfig):
    """Whisper-style bidirectional encoder (frontend embeds in, states out)."""
    spec = LayerSpec(mixer="attn", cross=False, ffn="dense")
    k1, k2 = jax.random.split(key)
    return {
        "stage": _init_stage(k1, (spec,), cfg.encoder_layers, cfg),
        "final_norm": norm_params(cfg, cfg.d_model),
        "pos": embed_init(k2, (max(cfg.encoder_seq, 1), cfg.d_model)),
    }


def init_params(key, cfg: ModelConfig):
    ks = jax.random.split(key, 8)
    plan = layer_plan(cfg)
    params = {
        "embed": embed_init(ks[0], (cfg.padded_vocab, cfg.d_model)),
        "stages": [
            _init_stage(ks[1 + i % 4], pattern, reps, cfg)
            for i, (pattern, reps) in enumerate(plan)
        ],
        "final_norm": norm_params(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["head"] = embed_init(ks[5], (cfg.d_model, cfg.padded_vocab))
    if cfg.pos_embed == "learned":
        params["pos"] = embed_init(ks[6], (cfg.max_position, cfg.d_model))
    if cfg.is_encdec:
        params["encoder"] = _init_encoder(ks[7], cfg)
    if cfg.mtp:
        km = jax.random.split(ks[4], 3)
        spec = LayerSpec(mixer="mla" if cfg.attention == "mla" else "attn",
                         cross=False, ffn="dense")
        params["mtp"] = {
            "proj": embed_init(km[0], (2 * cfg.d_model, cfg.d_model)),
            "norm_h": norm_params(cfg, cfg.d_model),
            "norm_e": norm_params(cfg, cfg.d_model),
            "layer": _init_sublayer(km[1], spec, cfg),
        }
    return params


# ====================================================== caches

def _reject_mla_int8(cfg: ModelConfig):
    """MLA caches store the *latent* KV (compressed projections consumed
    by einsum up-projections), which has no per-head int8 layout yet —
    fail at construction rather than silently keeping a bf16 pool."""
    if cfg.kv_dtype == "int8":
        raise ValueError(
            "kv_dtype='int8' is not supported with attention='mla': the "
            "latent KV cache has no quantized layout (use GQA, or "
            "kv_dtype='bf16' for MLA models)")


def _sublayer_cache(spec: LayerSpec, cfg: ModelConfig, batch: int,
                    max_len: int, dtype, cross_len: int):
    window = 0 if spec.mixer == "ssm" else effective_window(cfg)
    c = {}
    if spec.mixer == "attn":
        cap = attn.cache_capacity(cfg, max_len, window)
        hd = cfg.resolved_head_dim
        c["self"] = attn.make_kv_cache(batch, cap, cfg.n_kv_heads, hd, hd,
                                       dtype, quantized=cfg.kv_dtype == "int8")
    elif spec.mixer == "mla":
        _reject_mla_int8(cfg)
        cap = attn.cache_capacity(cfg, max_len, window)
        c["self"] = attn.make_mla_cache(batch, cap, cfg, dtype)
    else:
        c["self"] = ssm_mod.make_ssm_state(batch, cfg)
    if spec.cross:
        hd = cfg.resolved_head_dim
        c["cross"] = attn.make_kv_cache(batch, max(cross_len, 1),
                                        cfg.n_kv_heads, hd, hd, dtype)
    return c


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    """Decode/prefill cache pytree mirroring the stage structure."""
    cross_len = cfg.n_frontend_tokens if not cfg.is_encdec else cfg.encoder_seq
    stages = []
    for pattern, reps in layer_plan(cfg):
        per = []
        for j in range(len(pattern)):
            c = _sublayer_cache(pattern[j], cfg, batch, max_len, dtype,
                                cross_len)
            per.append(jax.tree.map(
                lambda x: jnp.broadcast_to(x, (reps,) + x.shape).copy(), c))
        stages.append(tuple(per))
    return {"stages": stages, "lengths": jnp.zeros((batch,), jnp.int32)}


def stack_caches(caches):
    """Concatenate per-request caches (batch axis 1 inside stages, axis 0
    for lengths) into one batched cache."""
    stages = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=1),
                          *[c["stages"] for c in caches])
    lengths = jnp.concatenate([c["lengths"] for c in caches], axis=0)
    return {"stages": stages, "lengths": lengths}


def split_cache(cache, n):
    """Inverse of stack_caches: n per-request caches."""
    return [{"stages": jax.tree.map(lambda x: x[:, i: i + 1], cache["stages"]),
             "lengths": cache["lengths"][i: i + 1]} for i in range(n)]


# ====================================================== slotted caches
#
# Continuous batching without host pytree traffic: one device-resident
# cache whose batch axis is a pool of request *slots*. Resident steps
# thread slot_idx all the way into the mixer write path (apply(...,
# slot_idx=...)): new KV rows / recurrent states are scattered in place
# into the active slots only (paged-attention style), and reads gather
# just the active rows — per-step cache byte traffic scales with the
# number of new tokens, not bucket x capacity. gather_slots survives for
# speculative snapshots (decode-and-discard rollback) and scatter_slots
# for slot resets on admission. Inside "stages" the slot (batch) axis is
# 1 (axis 0 is the scan-repeat axis); "lengths" carries it on axis 0 —
# the same layout stack_caches produces.

def gather_slots(cache, slot_idx):
    """Device-side gather of a compact sub-cache. slot_idx: (B,) int32.

    The result is structurally identical to `stack_caches` over those
    slots, so every existing step function runs on it unchanged."""
    stages = jax.tree.map(lambda x: jnp.take(x, slot_idx, axis=1),
                          cache["stages"])
    return {"stages": stages,
            "lengths": jnp.take(cache["lengths"], slot_idx, axis=0)}


def scatter_slots(cache, sub, slot_idx):
    """Inverse of gather_slots: write sub-cache rows back into their
    slots. Rows with duplicate indices (scratch-slot padding) resolve
    arbitrarily — only ever used for slots no request owns."""
    stages = jax.tree.map(lambda full, part: full.at[:, slot_idx].set(part),
                          cache["stages"], sub["stages"])
    lengths = cache["lengths"].at[slot_idx].set(sub["lengths"])
    return {"stages": stages, "lengths": lengths}


def concat_slots(cache, extra):
    """Append `extra`'s slots after `cache`'s (capacity growth)."""
    stages = jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=1),
                          cache["stages"], extra["stages"])
    lengths = jnp.concatenate([cache["lengths"], extra["lengths"]], axis=0)
    return {"stages": stages, "lengths": lengths}


def slot_decode_step(params, cfg: ModelConfig, tokens, cache, slot_idx,
                     frontend=None, page_view=None):
    """One decode step resident in the slotted cache. tokens: (B, 1);
    slot_idx: (B,). Writes land in place: only the new token's row of
    each active slot is touched. Rows mapped to the scratch slot are
    compute padding — their writes land in scratch and are never read.
    page_view: block-table view when the pool is paged (DESIGN.md §2.8)."""
    positions = jnp.take(cache["lengths"], slot_idx)[:, None]
    return apply(params, cfg, tokens, positions, cache=cache,
                 frontend=frontend, write=True, slot_idx=slot_idx,
                 page_view=page_view)


def slot_extend(params, cfg: ModelConfig, tokens, cache, slot_idx,
                frontend=None, token_mask=None, page_view=None):
    """Commit a (B, G) chain of accepted tokens into the slotted cache —
    in place: G rows per active slot, never the full sub-cache. frontend
    (modality embeddings) refreshes cross-attention rows for the active
    slots (prefill).

    token_mask: optional (B, G) bool — True for real tokens, False for a
    *suffix* of shape padding (chunked prefill's pad-and-mask final
    chunk). Masked tokens advance nothing: their KV rows are written
    with slot_pos = -1 (invisible to every read, and re-occupied by the
    next real tokens at those positions), SSM state/conv ignore them,
    and `lengths` advances by the real-token count only."""
    G = tokens.shape[1]
    positions = (jnp.take(cache["lengths"], slot_idx)[:, None]
                 + jnp.arange(G, dtype=jnp.int32))
    return apply(params, cfg, tokens, positions, cache=cache,
                 frontend=frontend, write=True, slot_idx=slot_idx,
                 token_mask=token_mask, page_view=page_view)


def slot_verify_chunk(params, cfg: ModelConfig, tokens, cache, slot_idx,
                      rel_pos, seg_mask, page_view=None):
    """Tree/chain verification against slot-resident caches (no commit).

    rel_pos: (B, G) node depths relative to each slot's length — absolute
    positions are resolved on device, so no host read of lengths."""
    positions = jnp.take(cache["lengths"], slot_idx)[:, None] + rel_pos
    logits, _, _ = apply(params, cfg, tokens, positions, cache=cache,
                         seg_mask=seg_mask, write=False, slot_idx=slot_idx,
                         page_view=page_view)
    return logits


# ====================================================== paged caches
#
# Paged slot caches (DESIGN.md §2.8): same structure as the slotted
# cache above except that attention/MLA "self" caches are *page pools*
# with leading (reps, n_pages, page_size, ...) instead of per-slot
# reserved rows (reps, pool, capacity, ...). A request owns an ordered
# list of physical pages (its block table, host-side in the manager);
# reads/writes go through a (B, n_view) `page_view` built from the block
# tables. SSM recurrent state, cross-attention caches and `lengths` stay
# slot-indexed — they are O(1) per request already. All helpers below
# take `cfg` (static under jit) because paged-ness is per-sublayer: only
# the layer plan knows which "self" caches are pools.

def _map_subcaches(cfg: ModelConfig, cache, fn):
    """Rebuild the stages list with fn(spec, subcache_dict) per sublayer."""
    stages = []
    for (pattern, _reps), scache in zip(layer_plan(cfg), cache["stages"]):
        stages.append(tuple(fn(pattern[j], scache[j])
                            for j in range(len(pattern))))
    return stages


def init_paged_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16, *,
                     page_size: int = 64, n_pages: int = 16):
    """Paged decode cache: attention/MLA KV in page pools, the rest slotted.

    Unlike `init_cache` there is no per-slot max_len — attention capacity
    is whatever the block tables map, so long contexts are not a special
    case. `batch` sizes only the slot-indexed leaves (SSM state, cross
    KV, lengths).
    """
    cross_len = cfg.n_frontend_tokens if not cfg.is_encdec else cfg.encoder_seq
    stages = []
    for pattern, reps in layer_plan(cfg):
        per = []
        for j in range(len(pattern)):
            spec = pattern[j]
            hd = cfg.resolved_head_dim
            c = {}
            if spec.mixer == "attn":
                c["self"] = attn.make_paged_kv_cache(
                    n_pages, page_size, cfg.n_kv_heads, hd, hd, dtype,
                    quantized=cfg.kv_dtype == "int8")
            elif spec.mixer == "mla":
                _reject_mla_int8(cfg)
                c["self"] = attn.make_paged_mla_cache(n_pages, page_size,
                                                      cfg, dtype)
            else:
                c["self"] = ssm_mod.make_ssm_state(batch, cfg)
            if spec.cross:
                c["cross"] = attn.make_kv_cache(batch, max(cross_len, 1),
                                                cfg.n_kv_heads, hd, hd, dtype)
            per.append(jax.tree.map(
                lambda x: jnp.broadcast_to(x, (reps,) + x.shape).copy(), c))
        stages.append(tuple(per))
    return {"stages": stages, "lengths": jnp.zeros((batch,), jnp.int32)}


def paged_pool_shape(cfg: ModelConfig, cache):
    """(n_pages, page_size) of the paged pools, or None if no attention."""
    for (pattern, _reps), scache in zip(layer_plan(cfg), cache["stages"]):
        for j, spec in enumerate(pattern):
            if spec.mixer in ("attn", "mla"):
                sp = scache[j]["self"]["slot_pos"]
                return sp.shape[1], sp.shape[2]
    return None


def gather_paged_slots(cfg: ModelConfig, cache, slot_idx, page_view):
    """Materialize a plain stacked sub-cache from a paged pool (speculative
    snapshots). The attention views gather only the mapped pages into
    (reps, B, n_view * ps, ...) — structurally identical to gather_slots'
    output with capacity C = n_view * ps, so drafting / rollback /
    extend_snapshot run on it unchanged. Unmapped view entries are NULL
    pages (slot_pos -1 ⇒ masked)."""
    B, nv = page_view.shape

    def gather(spec, c):
        nc = {}
        for key, sub in c.items():
            if key == "self" and spec.mixer in ("attn", "mla"):
                ps = sub["slot_pos"].shape[-1]
                rows = (page_view[:, :, None] * ps
                        + jnp.arange(ps, dtype=page_view.dtype)
                        ).reshape(B, nv * ps)
                nc[key] = {
                    f: jnp.take(
                        v.reshape((v.shape[0], v.shape[1] * v.shape[2])
                                  + v.shape[3:]),
                        rows, axis=1)
                    for f, v in sub.items()}
            else:
                nc[key] = jax.tree.map(
                    lambda v: jnp.take(v, slot_idx, axis=1), sub)
        return nc

    return {"stages": _map_subcaches(cfg, cache, gather),
            "lengths": jnp.take(cache["lengths"], slot_idx, axis=0)}


def reset_pages(cfg: ModelConfig, cache, page_ids):
    """Mark physical pages empty (slot_pos = -1) in every paged pool —
    page free/realloc. K/V payloads are left as garbage; masking is
    always against slot_pos so they are unreadable."""
    def reset(spec, c):
        if spec.mixer not in ("attn", "mla"):
            return c
        nc = dict(c)
        s = dict(c["self"])
        s["slot_pos"] = s["slot_pos"].at[:, page_ids].set(-1)
        nc["self"] = s
        return nc

    return {"stages": _map_subcaches(cfg, cache, reset),
            "lengths": cache["lengths"]}


def reset_slot_state(cfg: ModelConfig, cache, slot_idx):
    """Reset the slot-indexed leaves of a paged cache on (re-)admission:
    SSM state/conv/pos zeroed, cross rows emptied, lengths zeroed. The
    paged pools are untouched — page recycling is `reset_pages`."""
    def reset(spec, c):
        nc = dict(c)
        if spec.mixer == "ssm":
            nc["self"] = {f: v.at[:, slot_idx].set(0)
                          for f, v in c["self"].items()}
        if "cross" in c:
            cr = dict(c["cross"])
            cr["slot_pos"] = cr["slot_pos"].at[:, slot_idx].set(-1)
            nc["cross"] = cr
        return nc

    return {"stages": _map_subcaches(cfg, cache, reset),
            "lengths": cache["lengths"].at[slot_idx].set(0)}


def concat_slots_paged(cfg: ModelConfig, cache, extra):
    """Slot-capacity growth for a paged cache: slot-indexed leaves (SSM,
    cross, lengths) get `extra`'s slots appended; the shared page pools
    keep `cache`'s arrays (pool growth is `grow_pages`)."""
    plan = layer_plan(cfg)
    stages = []
    for (pattern, _reps), sc, se in zip(plan, cache["stages"],
                                        extra["stages"]):
        per = []
        for j in range(len(pattern)):
            spec = pattern[j]
            nc = {}
            for key in sc[j]:
                if key == "self" and spec.mixer in ("attn", "mla"):
                    nc[key] = sc[j][key]
                else:
                    nc[key] = jax.tree.map(
                        lambda a, b: jnp.concatenate([a, b], axis=1),
                        sc[j][key], se[j][key])
            per.append(nc)
        stages.append(tuple(per))
    lengths = jnp.concatenate([cache["lengths"], extra["lengths"]], axis=0)
    return {"stages": stages, "lengths": lengths}


def grow_pages(cfg: ModelConfig, cache, extra_pages: int):
    """Append `extra_pages` empty physical pages to every paged pool."""
    def grow(spec, c):
        if spec.mixer not in ("attn", "mla"):
            return c
        nc = dict(c)
        s = {}
        for f, v in c["self"].items():
            pad = jnp.full((v.shape[0], extra_pages) + v.shape[2:],
                           -1 if f == "slot_pos" else 0, v.dtype)
            s[f] = jnp.concatenate([v, pad], axis=1)
        nc["self"] = s
        return nc

    return {"stages": _map_subcaches(cfg, cache, grow),
            "lengths": cache["lengths"]}


# ====================================================== apply

def _apply_sublayer(spec: LayerSpec, p, cache, x, positions, cfg: ModelConfig,
                    *, seg_mask, write, kv_src, causal=True, slot_idx=None,
                    token_mask=None, page_view=None):
    """Returns (x, new_cache, aux). With slot_idx, `cache` is a resident
    slot pool (batch axis > B): mixers gather the active rows for reads
    and `new_cache` holds sub-sized *write deltas* (new KV rows / fresh
    recurrent states) instead of updated pool arrays — so the enclosing
    lax.scan stacks only new-token-sized outputs, and `apply` scatters
    the deltas into the donated resident cache once, at the top level of
    the jitted program.

    page_view (B, n_view): the attention/MLA "self" caches are paged page
    pools (DESIGN.md §2.8) — reads gather only the mapped pages; SSM
    state and cross-attention stay slot-indexed via slot_idx."""
    aux = jnp.zeros((), jnp.float32)
    window = 0 if spec.mixer == "ssm" else effective_window(cfg)
    h = apply_norm(p["ln1"], x, cfg)
    self_cache = cache.get("self") if cache is not None else None
    if spec.mixer == "attn":
        if causal:
            out, new_self = attn.gqa_attention(
                p["mixer"], cfg, h, positions, cache=self_cache,
                seg_mask=seg_mask, window=window, slot_idx=slot_idx,
                write=write, token_mask=token_mask, page_view=page_view)
        else:  # encoder: bidirectional, no rope
            out, new_self = _bidir_attention(p["mixer"], cfg, h)
    elif spec.mixer == "mla":
        out, new_self = attn.mla_attention(
            p["mixer"], cfg, h, positions, cache=self_cache,
            seg_mask=seg_mask, window=window, slot_idx=slot_idx, write=write,
            token_mask=token_mask, page_view=page_view)
    else:  # ssm
        out, new_self = ssm_mod.ssm_mixer(p["mixer"], cfg, h,
                                          state=self_cache,
                                          slot_idx=slot_idx, write=write,
                                          token_mask=token_mask)
    if not write:
        new_self = self_cache if slot_idx is None else None
    x = (x + out).astype(x.dtype)

    if slot_idx is not None:
        new_cache = {"self": new_self} if cache is not None else None
    else:
        new_cache = dict(cache) if cache is not None else None
        if new_cache is not None:
            new_cache["self"] = new_self if new_self is not None \
                else self_cache

    if spec.cross:
        h = apply_norm(p["ln_cross"], x, cfg)
        cross_cache = cache.get("cross") if cache is not None else None
        use_src = kv_src if (cross_cache is None or kv_src is not None) else None
        out, new_cross = attn.cross_attention(p["cross"], cfg, h,
                                              kv_src=use_src,
                                              cache=cross_cache,
                                              slot_idx=slot_idx, write=write)
        x = (x + out).astype(x.dtype)
        if new_cache is not None:
            new_cache["cross"] = new_cross

    if spec.ffn != "none":
        h = apply_norm(p["ln2"], x, cfg)
        if spec.ffn == "moe":
            out, aux = apply_moe(p["ffn"], h, cfg, cfg.moe)
        else:
            out = apply_mlp(p["ffn"], h, cfg)
        x = (x + out).astype(x.dtype)
    return x, new_cache, aux


def _bidir_attention(p, cfg: ModelConfig, h):
    """Encoder self-attention: bidirectional, no rope (learned pos already added)."""
    B, T, _ = h.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = (h @ p["wq"]).reshape(B, T, hq, hd)
    k = (h @ p["wk"]).reshape(B, T, hkv, hd)
    v = (h @ p["wv"]).reshape(B, T, hkv, hd)
    if cfg.qkv_bias:
        q = q + p["bq"].reshape(hq, hd)
        k = k + p["bk"].reshape(hkv, hd)
        v = v + p["bv"].reshape(hkv, hd)
    qg = q.reshape(B, T, hkv, hq // hkv, hd)
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    out = attn.blocked_attention(qg, k, v, pos, pos, scale=hd ** -0.5,
                                 causal=False)
    return out.reshape(B, T, hq * hd) @ p["wo"], None


def _scatter_stage_delta(scache, deltas, slot_idx, positions,
                         page_view=None):
    """Scatter one stage's stacked write deltas into the resident pool.

    scache: per-sublayer tuple of cache dicts with leading (reps, pool,
    ...); deltas: matching tuple of {"self"/"cross": delta | None} where
    a delta carries leading (reps, B, ...). Runs at the top level of the
    jitted step (outside the scan), so with buffer donation XLA updates
    the pool in place and per-step written bytes scale with the number
    of new tokens. Duplicate scratch rows resolve arbitrarily — scratch
    contents are never read.

    page_view (B, n_view): the attention/MLA "self" pools are paged —
    the write column c = pos % (n_view * ps) is translated through the
    block table to physical row page_view[b, c // ps] * ps + c % ps.
    The manager pre-allocates every page a write can touch, so writes
    never land on the NULL page (padding rows map to the scratch page)."""
    bidx = slot_idx[:, None]
    out = []
    for cj, dj in zip(scache, deltas):
        nc = dict(cj)
        for key, pool_c in cj.items():
            d = dj.get(key) if dj is not None else None
            if d is None:
                continue
            if "ssm" in d:          # recurrent state: per-slot replacement
                nc[key] = {f: pool_c[f].at[:, slot_idx].set(d[f])
                           for f in pool_c}
            elif key != "cross" and page_view is not None:
                # paged self-attention pool: block-table translated rows
                ps = pool_c["slot_pos"].shape[-1]
                n_pages = pool_c["slot_pos"].shape[1]
                col = positions % (page_view.shape[1] * ps)
                phys = (jnp.take_along_axis(page_view, col // ps, axis=1) * ps
                        + col % ps)                              # (B, T)
                upd = {}
                for f in pool_c:
                    rest = pool_c[f].shape[3:]
                    flat = pool_c[f].reshape(
                        (pool_c[f].shape[0], n_pages * ps) + rest)
                    upd[f] = flat.at[:, phys].set(d[f]).reshape(
                        pool_c[f].shape)
                nc[key] = upd
            else:                   # attention KV: new-token rows
                C = pool_c["slot_pos"].shape[-1]
                if key == "cross":  # full-row projections, columns 0..S
                    scol = jnp.arange(d["slot_pos"].shape[-1])[None, :]
                else:               # ring placement, as in write_kv
                    scol = positions % C
                nc[key] = {f: pool_c[f].at[:, bidx, scol].set(d[f])
                           for f in pool_c}
        out.append(nc)
    return tuple(out)


def _apply_stage(pattern, sparams, scache, x, positions, cfg: ModelConfig,
                 *, seg_mask, write, kv_src, causal=True, remat=False,
                 slot_idx=None, token_mask=None, page_view=None):
    def body(carry, xs):
        xx = carry
        lp, lc = xs
        aux_tot = jnp.zeros((), jnp.float32)
        new_lc = []
        for j, spec in enumerate(pattern):
            cj = lc[j] if lc is not None else None
            xx, ncj, aux = _apply_sublayer(
                spec, lp[j], cj, xx, positions, cfg,
                seg_mask=seg_mask, write=write, kv_src=kv_src, causal=causal,
                slot_idx=slot_idx, token_mask=token_mask,
                page_view=page_view)
            new_lc.append(ncj)
            aux_tot = aux_tot + aux
        return xx, (tuple(new_lc), aux_tot)

    if remat:
        body = jax.checkpoint(body)
    xs = (sparams, scache)
    x, (new_cache, auxs) = jax.lax.scan(body, x, xs)
    return x, new_cache, auxs.sum()


def _encode(params, cfg: ModelConfig, frontend):
    """Whisper encoder: frontend embeds (B, S, d) -> encoder states."""
    enc = params["encoder"]
    S = frontend.shape[1]
    x = frontend + enc["pos"][:S]
    spec = LayerSpec(mixer="attn", cross=False, ffn="dense")
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), frontend.shape[:2])
    x, _, _ = _apply_stage((spec,), enc["stage"], None, x, pos, cfg,
                           seg_mask=None, write=False, kv_src=None,
                           causal=False)
    return apply_norm(enc["final_norm"], x, cfg)


def _logits(params, cfg: ModelConfig, x):
    # tied_logits/qdot accept both plain f32/bf16 weights and the
    # weight-only-int8 {"w8","scale"} form (models/quantize.py)
    if cfg.tie_embeddings:
        logits = quantize.tied_logits(params["embed"], x).astype(jnp.float32)
    else:
        logits = quantize.qdot(x, params["head"]).astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab:
        neg = jnp.full((cfg.padded_vocab - cfg.vocab,), -1e30, jnp.float32)
        logits = logits.at[..., cfg.vocab:].set(neg)
    return logits


def apply(params, cfg: ModelConfig, tokens, positions=None, cache=None,
          frontend=None, seg_mask=None, write=True, remat=False,
          return_hidden=False, slot_idx=None, token_mask=None,
          page_view=None):
    """Unified forward.

    tokens:    (B, T) int32
    positions: (B, T) absolute positions (default arange)
    cache:     None (self-contained) or pytree from init_cache
    frontend:  (B, S, d) stub modality embeddings (audio/vlm)
    seg_mask:  (B, T, T) intra-segment mask (tree verification)
    write:     commit new KV/state into the returned cache
    slot_idx:  (B,) int32 — `cache` is a resident slot pool whose batch
               axis exceeds B; row b of tokens lives in pool slot
               slot_idx[b]. Writes touch only the new tokens' rows of the
               active slots (paged-attention-style in-place update);
               reads gather the active rows. The returned cache is the
               full pool.
    token_mask: (B, T) bool — real tokens True, suffix shape-padding
               False (slot path only; chunked prefill's pad-and-mask
               final chunk). Attention sees masked tokens at position -1
               (their KV rows land at the real column slots but with
               slot_pos = -1, so they are invisible and the next real
               tokens at those positions overwrite them); the SSM mixer
               freezes its state/conv across them; `lengths` advances by
               the real-token count only.
    page_view: (B, n_view) int32 — the slot pool's attention/MLA "self"
               caches are *paged* (init_paged_cache, DESIGN.md §2.8):
               entry [b, i] is the physical page holding request b's
               logical page i (NULL for unmapped tail entries). Reads
               gather only the view's pages; write deltas scatter
               through the block table. Requires slot_idx.
    Returns (logits (B,T,Vp) f32, new_cache, aux_loss) [+ hidden if asked].
    """
    B, T = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    if token_mask is not None:
        assert slot_idx is not None, "token_mask requires the slot path"
    if page_view is not None:
        assert slot_idx is not None, "page_view requires the slot path"
    dtype = jnp.dtype(cfg.dtype)
    x = quantize.embed_lookup(params["embed"], tokens, dtype)
    if cfg.pos_embed == "learned":
        x = x + params["pos"][positions].astype(dtype)

    kv_src = None
    if cfg.is_encdec:
        if frontend is not None:
            kv_src = _encode(params, cfg, frontend.astype(dtype))
    elif cfg.cross_attn_period:
        kv_src = frontend.astype(dtype) if frontend is not None else None

    aux_total = jnp.zeros((), jnp.float32)
    new_stages = []
    plan = layer_plan(cfg)
    cache_stages = cache["stages"] if cache is not None else [None] * len(plan)
    for (pattern, reps), sparams, scache in zip(plan, params["stages"],
                                                cache_stages):
        x, ncache, aux = _apply_stage(
            pattern, sparams, scache, x, positions, cfg,
            seg_mask=seg_mask, write=write, kv_src=kv_src, remat=remat,
            slot_idx=slot_idx, token_mask=token_mask, page_view=page_view)
        if slot_idx is not None and cache is not None:
            # resident path: the scan produced write deltas; scatter them
            # into the pool here (top level, donated buffers)
            ncache = (_scatter_stage_delta(scache, ncache, slot_idx,
                                           positions, page_view)
                      if write else scache)
        new_stages.append(ncache)
        aux_total = aux_total + aux

    x = apply_norm(params["final_norm"], x, cfg)
    logits = _logits(params, cfg, x)

    new_cache = None
    if cache is not None:
        new_len = cache["lengths"]
        if write:
            if slot_idx is None:
                new_len = jnp.maximum(new_len, positions[:, -1] + 1)
            else:
                # masked suffix tokens never advance the slot length (the
                # max masked position is the last *real* one; an
                # all-masked row yields -1 and leaves the length as-is)
                last = (positions[:, -1] if token_mask is None
                        else jnp.where(token_mask, positions, -1).max(-1))
                upd = jnp.maximum(jnp.take(new_len, slot_idx), last + 1)
                new_len = new_len.at[slot_idx].set(upd)
        new_cache = {"stages": new_stages, "lengths": new_len}
    if return_hidden:
        return logits, new_cache, aux_total, x
    return logits, new_cache, aux_total


# ====================================================== losses

def lm_loss(params, cfg: ModelConfig, tokens, frontend=None, remat=True):
    """Next-token CE (+ MoE aux + MTP aux when configured)."""
    logits, _, aux, hidden = apply(params, cfg, tokens, frontend=frontend,
                                   remat=remat, return_hidden=True)
    tgt = tokens[:, 1:]
    lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    loss = nll.mean()
    total = loss + 0.001 * aux

    if cfg.mtp:
        total = total + 0.3 * _mtp_loss(params, cfg, tokens, hidden)
    return total, {"lm": loss, "aux": aux}


def _mtp_loss(params, cfg: ModelConfig, tokens, hidden):
    """DeepSeek-V3 depth-1 multi-token prediction: predict t+2 from
    (h_t, emb(x_{t+1})) through one extra transformer layer."""
    mtp = params["mtp"]
    dtype = hidden.dtype
    B, T = tokens.shape
    h = apply_norm(mtp["norm_h"], hidden[:, : T - 1], cfg)
    e = apply_norm(mtp["norm_e"],
                   quantize.embed_lookup(params["embed"], tokens[:, 1:],
                                         dtype), cfg)
    x = jnp.concatenate([h, e], axis=-1) @ mtp["proj"].astype(dtype)
    spec = LayerSpec(mixer="mla" if cfg.attention == "mla" else "attn",
                     cross=False, ffn="dense")
    pos = jnp.broadcast_to(jnp.arange(T - 1, dtype=jnp.int32), (B, T - 1))
    x, _, _ = _apply_sublayer(spec, mtp["layer"], None, x, pos, cfg,
                              seg_mask=None, write=False, kv_src=None)
    x = apply_norm(params["final_norm"], x, cfg)
    logits = _logits(params, cfg, x)
    tgt = tokens[:, 2:]
    lp = jax.nn.log_softmax(logits[:, : T - 2], axis=-1)
    nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    return nll.mean()


# ====================================================== convenience wrappers

def prefill(params, cfg: ModelConfig, tokens, cache, frontend=None):
    positions = jnp.broadcast_to(
        jnp.arange(tokens.shape[1], dtype=jnp.int32), tokens.shape)
    return apply(params, cfg, tokens, positions, cache=cache,
                 frontend=frontend, write=True)


def decode_step(params, cfg: ModelConfig, tokens, cache, frontend=None):
    """tokens: (B, 1) next tokens at positions cache['lengths']."""
    positions = cache["lengths"][:, None]
    return apply(params, cfg, tokens, positions, cache=cache,
                 frontend=frontend, write=True)


def verify_chunk(params, cfg: ModelConfig, tokens, cache, positions=None,
                 seg_mask=None, write=False):
    """Score a draft segment (chain or tree) against the cache without
    committing. tokens: (B, G); positions default chain continuation."""
    B, G = tokens.shape
    if positions is None:
        positions = cache["lengths"][:, None] + jnp.arange(G, dtype=jnp.int32)
    if seg_mask is None:
        seg_mask = jnp.broadcast_to(
            jnp.tril(jnp.ones((G, G), bool)), (B, G, G))
    return apply(params, cfg, tokens, positions, cache=cache,
                 seg_mask=seg_mask, write=write)


def extend(params, cfg: ModelConfig, tokens, cache, frontend=None):
    """Commit accepted tokens (chain) into the cache; returns logits too."""
    B, G = tokens.shape
    positions = cache["lengths"][:, None] + jnp.arange(G, dtype=jnp.int32)
    return apply(params, cfg, tokens, positions, cache=cache,
                 frontend=frontend, write=True)
