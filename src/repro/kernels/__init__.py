"""Pallas TPU kernels with pure-jnp oracles (`ops.py` = jit'd
entry points, `ref.py` = reference semantics, tested equal): flash
decode over dense/slot/paged KV (`decode_attention`), token-tree
verification attention (`tree_attention`), and the Mamba2 SSD
intra-chunk scan (`ssd_scan`). All run in interpret mode on CPU.
"""
# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
