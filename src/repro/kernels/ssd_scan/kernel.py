"""Mamba2 SSD chunked-scan Pallas kernel (TPU target).

Layout: grid (B, n_head_blocks, n_chunks); the chunk dimension is
sequential ("arbitrary") and the (head_block, P, N) recurrent state lives
in VMEM scratch across chunk iterations — the inter-chunk recurrence never
round-trips HBM. Within a chunk the dual ("attention-like") form runs on
the MXU: (Q x N) x (N x Q) score matmuls and (Q x Q) x (Q x P) output
matmuls, Q = chunk_size (default 128, MXU-aligned).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _make_ssd_kernel(*, Q, hb, P, N, nc):
    def kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, init_ref,
               y_ref, final_ref, state_s):
        ci = pl.program_id(2)

        @pl.when(ci == 0)
        def _init():
            state_s[...] = init_ref[0].astype(jnp.float32)

        x = x_ref[0].astype(jnp.float32)          # (Q, hb, P)
        dt = dt_ref[0].astype(jnp.float32)        # (Q, hb)
        A = a_ref[...].astype(jnp.float32)        # (hb,)
        Bm = b_ref[0].astype(jnp.float32)         # (Q, hb, N)
        Cm = c_ref[0].astype(jnp.float32)         # (Q, hb, N)

        dA = dt * A[None, :]                      # (Q, hb) negative
        dAc = jnp.cumsum(dA, axis=0)              # (Q, hb)

        seg = dAc[:, None, :] - dAc[None, :, :]   # (Q, Q, hb)
        causal = jnp.tril(jnp.ones((Q, Q), jnp.bool_))
        Lmat = jnp.where(causal[:, :, None], jnp.exp(seg), 0.0)
        scores = jnp.einsum("qhn,khn->qkh", Cm, Bm) * Lmat
        xdt = x * dt[:, :, None]
        y_intra = jnp.einsum("qkh,khp->qhp", scores, xdt)

        state = state_s[...]                       # (hb, P, N)
        y_inter = jnp.einsum("qhn,hpn->qhp", Cm, state) \
            * jnp.exp(dAc)[:, :, None]
        y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

        chunk_decay = jnp.exp(dAc[Q - 1])          # (hb,)
        decay_to_end = jnp.exp(dAc[Q - 1][None, :] - dAc)  # (Q, hb)
        state_add = jnp.einsum("qhn,qh,qhp->hpn", Bm, decay_to_end * dt, x)
        state_s[...] = state * chunk_decay[:, None, None] + state_add

        @pl.when(ci == nc - 1)
        def _final():
            final_ref[0] = state_s[...].astype(final_ref.dtype)

    return kernel


def ssd_scan_pallas(x, dt, A, Bh, Ch, chunk, initial_state,
                    head_block: int = 8, interpret: bool = True):
    """x: (b, L, H, P); dt: (b, L, H); A: (H,); Bh/Ch: (b, L, H, N)
    (groups pre-broadcast to heads); initial_state: (b, H, P, N).
    L must be a multiple of `chunk` (ops.py pads). Returns (y, final)."""
    b, L, H, P = x.shape
    N = Bh.shape[-1]
    hb = min(head_block, H)
    assert H % hb == 0 and L % chunk == 0
    nc = L // chunk
    grid = (b, H // hb, nc)

    kernel = _make_ssd_kernel(Q=chunk, hb=hb, P=P, N=N, nc=nc)
    y, final = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, hb, P), lambda i, j, c: (i, c, j, 0)),
            pl.BlockSpec((1, chunk, hb), lambda i, j, c: (i, c, j)),
            pl.BlockSpec((hb,), lambda i, j, c: (j,)),
            pl.BlockSpec((1, chunk, hb, N), lambda i, j, c: (i, c, j, 0)),
            pl.BlockSpec((1, chunk, hb, N), lambda i, j, c: (i, c, j, 0)),
            pl.BlockSpec((1, hb, P, N), lambda i, j, c: (i, j, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, hb, P), lambda i, j, c: (i, c, j, 0)),
            pl.BlockSpec((1, hb, P, N), lambda i, j, c: (i, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, L, H, P), x.dtype),
            jax.ShapeDtypeStruct((b, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hb, P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bh, Ch, initial_state)
    return y, final
