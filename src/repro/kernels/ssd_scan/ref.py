"""Oracle for the SSD kernel: the naive O(L) recurrence from
`repro.models.ssm.ssd_reference` (h_t = exp(dt A) h_{t-1} + dt B x_t;
y_t = C h_t), plus the pure-jnp chunked form for cross-checks."""
from repro.models.ssm import ssd_reference, ssd_chunked  # noqa: F401
