"""jit'd wrapper for the SSD Pallas kernel — same API as
`repro.models.ssm.ssd_chunked` so `ssm_mixer(use_kernel=True)` swaps it in."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan_pallas


@partial(jax.jit, static_argnames=("chunk", "interpret", "head_block"))
def ssd(x, dt, A, B, C, chunk=128, initial_state=None, *,
        head_block: int = 8, interpret: bool = True):
    """x: (b, L, H, P); dt: (b, L, H); A: (H,); B/C: (b, L, G, N).
    Returns (y (b, L, H, P), final_state (b, H, P, N))."""
    b, L, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    chunk = min(chunk, L)
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=2).astype(jnp.float32)
    Ch = jnp.repeat(C, rep, axis=2).astype(jnp.float32)
    if initial_state is None:
        initial_state = jnp.zeros((b, H, P, N), jnp.float32)

    pad = (-L) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bh = jnp.pad(Bh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Ch = jnp.pad(Ch, ((0, 0), (0, pad), (0, 0), (0, 0)))

    hb = head_block
    while H % hb:
        hb //= 2
    y, final = ssd_scan_pallas(x, dt.astype(jnp.float32), A, Bh, Ch, chunk,
                               initial_state, head_block=max(hb, 1),
                               interpret=interpret)
    return y[:, :L], final
