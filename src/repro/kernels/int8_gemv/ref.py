"""Pure-jnp oracle for the fused int8 dequant-GEMV."""
from __future__ import annotations

import jax.numpy as jnp


def int8_gemv_ref(x, w8, scale):
    """y = (x @ w8) * scale, f32 accumulation.

    x: (B, K) activations (any float dtype); w8: (K, N) int8 weights;
    scale: (1, N) or (N,) f32 per-output-channel scales (absmax/127
    along K). Returns (B, N) f32. The reduction is a single dot over
    the full K axis — the kernel tiles only the output (N) axis, so in
    interpret mode the two are bitwise-identical on tile-aligned
    shapes (column tiling never reorders a per-element K reduction).
    """
    y = jnp.dot(x.astype(jnp.float32), w8.astype(jnp.float32),
                preferred_element_type=jnp.float32)
    return y * scale.reshape(1, -1)
