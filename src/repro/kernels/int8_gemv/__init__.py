"""Fused dequant-and-GEMV Pallas kernel for the int8 drafter decode
hot path (weights stay int8 in HBM, per-output-channel scales applied
in-register). `ops.py` = jit'd entry points (Pallas kernel + the
blocked XLA path used on CPU hosts), `ref.py` = pure-jnp oracle."""
