"""Pallas TPU kernel: fused int8 dequant + GEMV for drafter decode.

The decode matvec at drafter batch sizes (B <= 8) is memory-roofline-
bound on the weight stream (DESIGN.md §3.2): the win of weight-only
int8 is that HBM reads halve, *provided the dequant never round-trips
through memory*. This kernel streams int8 weight tiles into VMEM,
converts to f32 in-register, reduces over the full K axis with one MXU
dot, and applies the per-output-channel scale to the accumulator —
the activation block (B x K, small at decode shapes) stays resident in
VMEM across the whole grid.

Grid: 1-D over output tiles (N / block_n). K is NOT tiled: a single
dot per tile keeps the reduction order identical to the pure-jnp
oracle (`ref.int8_gemv_ref`), making kernel-vs-oracle comparisons
bitwise on tile-aligned shapes. Drafter d_ff/d_model sizes comfortably
fit a full (K, block_n) int8 tile in VMEM (K=4096, bn=128 -> 512 KiB).

Tiling constraints (TPU int8 min tile (32, 128)): K % 32 == 0,
block_n % 128 == 0, B padded to 8 by the op wrapper.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _int8_gemv_kernel(x_ref, w_ref, s_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)        # (B, K) activations
    w = w_ref[...].astype(jnp.float32)        # (K, bn) int8 -> f32 in-reg
    y = jnp.dot(x, w, preferred_element_type=jnp.float32)
    o_ref[...] = y * s_ref[...]               # (1, bn) scale broadcast


@partial(jax.jit, static_argnames=("block_n", "interpret"))
def int8_gemv_call(x, w8, scale, *, block_n: int = 128,
                   interpret: bool = False):
    """Raw pallas_call on pre-padded operands.

    x: (B, K) float; w8: (K, N) int8; scale: (1, N) f32 with
    K % 32 == 0, N % block_n == 0 and block_n % 128 == 0 (the op
    wrapper in ops.py pads arbitrary shapes). Returns (B, N) f32.
    """
    B, K = x.shape
    N = w8.shape[1]
    assert w8.shape[0] == K and scale.shape == (1, N)
    assert N % block_n == 0, (N, block_n)
    grid = (N // block_n,)
    return pl.pallas_call(
        _int8_gemv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((B, K), lambda i: (0, 0)),          # x resident
            pl.BlockSpec((K, block_n), lambda i: (0, i)),    # int8 stream
            pl.BlockSpec((1, block_n), lambda i: (0, i)),    # scales
        ],
        out_specs=pl.BlockSpec((B, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.float32),
        interpret=interpret,
    )(x, w8, scale)
