"""Jit'd entry points for the fused int8 dequant-GEMV.

Two paths behind one contract (y = (x @ w8) * scale, f32 accumulate):

* :func:`int8_gemv` — the Pallas TPU kernel (interpret-mode on CPU),
  padding arbitrary shapes to the int8 tile grid. Bitwise-equal to
  `ref.int8_gemv_ref` on tile-aligned shapes (K % 32, N % 128, the
  wrapper pads B); padded-K shapes are allclose (the zero-padded tail
  can reorder the SIMD reduction).
* :func:`int8_gemv_xla` — a K-blocked `lax.scan` formulation for
  hosts without a TPU lowering: each int8 block dequantizes into a
  cache-resident f32 tile, so HBM traffic stays ~1 byte/weight instead
  of the materialized-convert 4 bytes XLA:CPU emits for a plain
  dequant-then-dot. This is the path `benchmarks/kernel_bench.py`
  times against the bf16 dense matvec.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.int8_gemv.kernel import int8_gemv_call


def _pad_axis(a, mult, axis):
    pad = (-a.shape[axis]) % mult
    if not pad:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


@partial(jax.jit, static_argnames=("block_n", "interpret"))
def int8_gemv(x, w8, scale, *, block_n: int = 128, interpret: bool = False):
    """Fused dequant-GEMV: x (B, K) float, w8 (K, N) int8, scale (N,)
    or (1, N) f32 per-output-channel. Returns (B, N) f32."""
    B, K = x.shape
    N = w8.shape[1]
    scale = scale.reshape(1, N)
    xp = _pad_axis(_pad_axis(x, 8, 0), 32, 1)
    wp = _pad_axis(_pad_axis(w8, 32, 0), block_n, 1)
    sp = _pad_axis(scale, block_n, 1)
    out = int8_gemv_call(xp, wp, sp, block_n=block_n, interpret=interpret)
    return out[:B, :N]


@partial(jax.jit, static_argnames=("block_k",))
def int8_gemv_xla(x, w8, scale, *, block_k: int = 128):
    """K-blocked XLA formulation (CPU-friendly, see module docstring).

    Accumulation order differs from the single-dot oracle (per-block
    partial sums), so this path is allclose — not bitwise — to
    `ref.int8_gemv_ref`.
    """
    B, K = x.shape
    N = w8.shape[1]
    scale = scale.reshape(1, N)
    xp = _pad_axis(x.astype(jnp.float32), block_k, 1)
    wp = _pad_axis(w8, block_k, 0)
    nb = xp.shape[1] // block_k

    def body(acc, i):
        blk = jax.lax.dynamic_slice_in_dim(wp, i * block_k, block_k, 0)
        xb = jax.lax.dynamic_slice_in_dim(xp, i * block_k, block_k, 1)
        return acc + xb @ blk.astype(jnp.float32), None

    acc, _ = jax.lax.scan(body, jnp.zeros((B, N), jnp.float32),
                          jnp.arange(nb))
    return acc * scale
