"""Pure-jnp oracle for tree-attention verification."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def tree_attention_ref(q, k_cache, v_cache, cache_pos, k_seg, v_seg,
                       q_pos, seg_mask, *, scale, window=0):
    """q: (B, Hkv, R, Dk) rows = draft-tree nodes x GQA group;
    k_cache/v_cache: (B, Hkv, S, Dk/Dv) with slot positions cache_pos (B,S);
    k_seg/v_seg: (B, Hkv, M, Dk/Dv) fresh tree-node KV; seg_mask (B, R, M)
    ancestor mask. Returns (B, Hkv, R, Dv) f32."""
    qf = q.astype(jnp.float32)

    s_hist = jnp.einsum("bhrd,bhsd->bhrs", qf, k_cache.astype(jnp.float32)) * scale
    valid = (cache_pos >= 0)[:, None, None, :] & \
        (cache_pos[:, None, None, :] <= q_pos[:, None, :, None])
    if window > 0:
        valid = valid & (q_pos[:, None, :, None] - cache_pos[:, None, None, :]
                         < window)
    s_hist = jnp.where(valid, s_hist, NEG_INF)

    s_seg = jnp.einsum("bhrd,bhmd->bhrm", qf, k_seg.astype(jnp.float32)) * scale
    s_seg = jnp.where(seg_mask[:, None], s_seg, NEG_INF)

    s = jnp.concatenate([s_hist, s_seg], axis=-1)
    p = jax.nn.softmax(s, axis=-1)
    vv = jnp.concatenate([v_cache, v_seg], axis=2).astype(jnp.float32)
    return jnp.einsum("bhrs,bhsd->bhrd", p, vv)
