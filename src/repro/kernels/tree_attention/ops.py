"""jit'd wrapper: tree-attention verification = flash partial over the KV
cache merged with a masked flash partial over the fresh tree segment."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.common import flash_attention_partial, merge_partials


@partial(jax.jit, static_argnames=("scale", "window", "interpret",
                                   "block_q", "block_k"))
def tree_attention(q, k_cache, v_cache, cache_pos, k_seg, v_seg, q_pos,
                   seg_mask, *, scale, window=0, interpret=True,
                   block_q=128, block_k=128):
    """Same signature/semantics as ref.tree_attention_ref (docs there)."""
    hist = flash_attention_partial(
        q, k_cache, v_cache, q_pos, cache_pos, scale=scale, causal=True,
        window=window, block_q=block_q, block_k=block_k, interpret=interpret)
    seg_pos = jnp.zeros(k_seg.shape[:1] + k_seg.shape[2:3], jnp.int32)
    seg = flash_attention_partial(
        q, k_seg, v_seg, q_pos, seg_pos, scale=scale, causal=False,
        window=0, mask=seg_mask, block_q=block_q,
        block_k=max(8, k_seg.shape[2]), interpret=interpret)
    return merge_partials([hist, seg])
