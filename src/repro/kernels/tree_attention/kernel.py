"""Tree-attention Pallas kernel (TPU): the `pl.pallas_call` + BlockSpec
construction lives in `repro.kernels.common.flash_attention_partial`
(shared with decode_attention). This module pins the tree-verification
specialization: masked segment pass + cache pass, 128-aligned blocks.

Grid: (B, Hkv, n_q_blocks, n_kv_blocks), last dim sequential ("arbitrary"),
VMEM scratch carries (m, l, acc) across KV blocks; the tree ancestor mask
streams in (block_q, block_k) tiles.
"""
from repro.kernels.common import (flash_attention_partial, merge_partials,
                                  _make_kernel)

__all__ = ["flash_attention_partial", "merge_partials", "_make_kernel"]
