"""Shared Pallas flash-attention machinery (TPU target, interpret-mode
validated on CPU).

One partial-softmax flash kernel covers the framework's attention hot
spots; wrappers in tree_attention/ and decode_attention/ specialize block
shapes and compose partials (cache + draft-tree segment merge — the
flash-decoding trick generalized to CoSine's tree verification).

The kernel emits *unnormalized* (acc, m, l) so multiple KV sources can be
merged exactly before the final normalization (see merge_partials).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _make_kernel(*, scale, causal, window, nk, has_mask,
                 block_q, block_k, dk, dv):
    def kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, *rest):
        if has_mask:
            mask_ref, acc_out, m_out, l_out, m_s, l_s, acc_s = rest
        else:
            acc_out, m_out, l_out, m_s, l_s, acc_s = rest
            mask_ref = None
        kb = pl.program_id(3)

        @pl.when(kb == 0)
        def _init():
            m_s[...] = jnp.full((block_q,), NEG_INF, jnp.float32)
            l_s[...] = jnp.zeros((block_q,), jnp.float32)
            acc_s[...] = jnp.zeros((block_q, dv), jnp.float32)

        q = q_ref[0, 0].astype(jnp.float32)          # (bq, Dk)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, Dk)
        v = v_ref[0, 0].astype(jnp.float32)          # (bk, Dv)
        qpos = qpos_ref[0]                           # (bq,)
        kpos = kpos_ref[0]                           # (bk,)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        valid = (kpos >= 0)[None, :]
        if causal:
            valid = valid & (kpos[None, :] <= qpos[:, None])
        if window > 0:
            valid = valid & (qpos[:, None] - kpos[None, :] < window)
        if mask_ref is not None:
            valid = valid & mask_ref[0]
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.where(valid, jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_s[...] = l_s[...] * corr + p.sum(axis=1)
        acc_s[...] = acc_s[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_s[...] = m_new

        @pl.when(kb == nk - 1)
        def _out():
            acc_out[0, 0] = acc_s[...].astype(acc_out.dtype)
            m_out[0, 0] = m_s[...]
            l_out[0, 0] = l_s[...]

    return kernel


def _pad_to(x, size, axis, value=0):
    pad = size - x.shape[axis]
    if pad <= 0:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg, constant_values=value)


def flash_attention_partial(q, k, v, q_pos, k_pos, *, scale, causal=True,
                            window=0, mask=None, block_q=128, block_k=128,
                            interpret=True):
    """Blocked flash attention returning unnormalized partials.

    q: (B, Hkv, R, Dk) — R query rows (tokens x GQA group, pre-expanded)
    k: (B, Hkv, S, Dk); v: (B, Hkv, S, Dv)
    q_pos: (B, R); k_pos: (B, S); mask: optional (B, R, S) bool
    Returns acc (B, Hkv, R, Dv) f32, m (B, Hkv, R) f32, l (B, Hkv, R) f32.
    """
    B, H, R, Dk = q.shape
    S = k.shape[2]
    Dv = v.shape[3]
    block_q = max(8, min(block_q, R))
    block_k = max(8, min(block_k, S))
    Rp = math.ceil(R / block_q) * block_q
    Sp = math.ceil(S / block_k) * block_k

    q = _pad_to(q, Rp, 2)
    k = _pad_to(k, Sp, 2)
    v = _pad_to(v, Sp, 2)
    q_pos = _pad_to(q_pos.astype(jnp.int32), Rp, 1)
    k_pos = _pad_to(k_pos.astype(jnp.int32), Sp, 1, value=-1)
    if mask is not None:
        mask = _pad_to(_pad_to(mask, Rp, 1), Sp, 2)

    nq, nk = Rp // block_q, Sp // block_k
    grid = (B, H, nq, nk)

    in_specs = [
        pl.BlockSpec((1, block_q), lambda b, h, iq, ik: (b, iq)),
        pl.BlockSpec((1, block_k), lambda b, h, iq, ik: (b, ik)),
        pl.BlockSpec((1, 1, block_q, Dk), lambda b, h, iq, ik: (b, h, iq, 0)),
        pl.BlockSpec((1, 1, block_k, Dk), lambda b, h, iq, ik: (b, h, ik, 0)),
        pl.BlockSpec((1, 1, block_k, Dv), lambda b, h, iq, ik: (b, h, ik, 0)),
    ]
    args = [q_pos, k_pos, q, k, v]
    if mask is not None:
        in_specs.append(pl.BlockSpec((1, block_q, block_k),
                                     lambda b, h, iq, ik: (b, iq, ik)))
        args.append(mask)

    out_specs = [
        pl.BlockSpec((1, 1, block_q, Dv), lambda b, h, iq, ik: (b, h, iq, 0)),
        pl.BlockSpec((1, 1, block_q), lambda b, h, iq, ik: (b, h, iq)),
        pl.BlockSpec((1, 1, block_q), lambda b, h, iq, ik: (b, h, iq)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((B, H, Rp, Dv), jnp.float32),
        jax.ShapeDtypeStruct((B, H, Rp), jnp.float32),
        jax.ShapeDtypeStruct((B, H, Rp), jnp.float32),
    ]

    kernel = _make_kernel(scale=scale, causal=causal, window=window, nk=nk,
                          has_mask=mask is not None, block_q=block_q,
                          block_k=block_k, dk=Dk, dv=Dv)
    acc, m, l = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(*args)
    return acc[:, :, :R], m[:, :, :R], l[:, :, :R]


def merge_partials(parts):
    """Exactly merge [(acc, m, l), ...] partials; returns normalized out."""
    acc, m, l = parts[0]
    for acc2, m2, l2 in parts[1:]:
        m_new = jnp.maximum(m, m2)
        e1 = jnp.exp(m - m_new)
        e2 = jnp.exp(m2 - m_new)
        acc = acc * e1[..., None] + acc2 * e2[..., None]
        l = l * e1 + l2 * e2
        m = m_new
    l = jnp.where(l == 0.0, 1.0, l)
    return acc / l[..., None]
