"""Pure-jnp oracle for single-token (GQA flash-decode) attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k_cache, v_cache, cache_pos, q_pos, *,
                         scale, window=0):
    """q: (B, Hkv, G, Dk) one token's queries (G = GQA group);
    k_cache/v_cache: (B, Hkv, S, Dk/Dv); cache_pos (B, S); q_pos (B,).
    Returns (B, Hkv, G, Dv) f32."""
    s = jnp.einsum("bhgd,bhsd->bhgs", q.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    valid = (cache_pos >= 0)[:, None, None, :] & \
        (cache_pos[:, None, None, :] <= q_pos[:, None, None, None])
    if window > 0:
        valid = valid & (q_pos[:, None, None, None]
                         - cache_pos[:, None, None, :] < window)
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgs,bhsd->bhgd", p, v_cache.astype(jnp.float32))


def decode_attention_slots_ref(q, k_cache, v_cache, cache_pos, q_pos,
                               slot_idx, *, scale, window=0):
    """Oracle for the slot-indexed read: caches hold the full slot pool
    (S_pool, Hkv, C, D); only rows slot_idx (B,) are attended."""
    k = jnp.take(k_cache, slot_idx, axis=0)
    v = jnp.take(v_cache, slot_idx, axis=0)
    cp = jnp.take(cache_pos, slot_idx, axis=0)
    return decode_attention_ref(q, k, v, cp, q_pos, scale=scale,
                                window=window)


def decode_attention_paged_ref(q, k_pages, v_pages, page_pos, q_pos,
                               block_tables, *, scale, window=0):
    """Oracle for the paged read: gather each request's pages by its
    block table into a contiguous (B, Hkv, n_view*ps, D) view, then run
    the dense decode oracle. k_pages/v_pages: (P, Hkv, ps, Dk/Dv);
    page_pos: (P, ps); block_tables: (B, n_view) int32."""
    def _view(pages):
        g = jnp.take(pages, block_tables, axis=0)      # (B, nv, H, ps, D)
        g = jnp.moveaxis(g, 2, 1)                      # (B, H, nv, ps, D)
        return g.reshape(g.shape[0], g.shape[1], -1, g.shape[-1])
    cp = jnp.take(page_pos, block_tables, axis=0).reshape(q.shape[0], -1)
    return decode_attention_ref(q, _view(k_pages), _view(v_pages), cp, q_pos,
                                scale=scale, window=window)
