"""Flash-decode Pallas kernel (TPU): the `pl.pallas_call` + BlockSpec
construction lives in `repro.kernels.common.flash_attention_partial`
(shared with tree_attention). This module pins the decode specialization:
the GQA group is the row dimension (q block = (G, Dk), G padded to 8), KV
streams in long blocks (default 512) to maximize HBM read efficiency —
the decode step is memory-roofline-bound (DESIGN.md §3.2).
"""
from repro.kernels.common import (flash_attention_partial, merge_partials,
                                  _make_kernel)

__all__ = ["flash_attention_partial", "merge_partials", "_make_kernel"]
