"""Flash-decode Pallas kernels (TPU): the dense `pl.pallas_call` +
BlockSpec construction lives in `repro.kernels.common
.flash_attention_partial` (shared with tree_attention). This module pins
the decode specializations:

* dense/slot decode — the GQA group is the row dimension (q block =
  (G, Dk), G padded to 8), KV streams in long blocks (default 512) to
  maximize HBM read efficiency — the decode step is memory-roofline-
  bound (DESIGN.md §3.2).
* paged decode (`paged_flash_decode`) — the KV cache is a page *pool*
  (DESIGN.md §2.8) and each request's block table is a scalar-prefetch
  operand: the grid walks (batch, head, logical page) and the BlockSpec
  index maps dereference `tbl[b, lp]` to stream exactly the pages the
  request holds, so per-step HBM traffic is ∝ tokens held, never pool
  capacity, with no gather materialized outside the kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import (NEG_INF, _pad_to, flash_attention_partial,
                                  merge_partials, _make_kernel)

__all__ = ["flash_attention_partial", "merge_partials", "_make_kernel",
           "paged_flash_decode"]


def _make_paged_kernel(*, scale, window, nv, block_q, dv):
    def kernel(tbl_ref, qpos_ref, kpos_ref, q_ref, k_ref, v_ref,
               acc_out, m_out, l_out, m_s, l_s, acc_s):
        del tbl_ref  # consumed by the BlockSpec index maps
        lp = pl.program_id(2)

        @pl.when(lp == 0)
        def _init():
            m_s[...] = jnp.full((block_q,), NEG_INF, jnp.float32)
            l_s[...] = jnp.zeros((block_q,), jnp.float32)
            acc_s[...] = jnp.zeros((block_q, dv), jnp.float32)

        q = q_ref[0, 0].astype(jnp.float32)          # (bq, Dk)
        k = k_ref[0, 0].astype(jnp.float32)          # (ps, Dk)
        v = v_ref[0, 0].astype(jnp.float32)          # (ps, Dv)
        qpos = qpos_ref[0]                           # (bq,)
        kpos = kpos_ref[0]                           # (ps,)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        valid = (kpos >= 0)[None, :] & (kpos[None, :] <= qpos[:, None])
        if window > 0:
            valid = valid & (qpos[:, None] - kpos[None, :] < window)
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.where(valid, jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_s[...] = l_s[...] * corr + p.sum(axis=1)
        acc_s[...] = acc_s[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_s[...] = m_new

        @pl.when(lp == nv - 1)
        def _out():
            acc_out[0, 0] = acc_s[...]
            m_out[0, 0] = m_s[...]
            l_out[0, 0] = l_s[...]

    return kernel


def paged_flash_decode(q, k_pages, v_pages, page_pos, q_pos, block_tables,
                       *, scale, window=0, interpret=True):
    """Decode-over-pool flash attention partials (unnormalized).

    q: (B, Hkv, G, Dk) one token's queries (G = GQA group rows);
    k_pages/v_pages: (P, Hkv, ps, Dk/Dv) physical page pool;
    page_pos: (P, ps) absolute position stored in each pool row (-1 =
    empty — NULL/unwritten pages mask to exact no-ops);
    q_pos: (B,); block_tables: (B, n_view) int32 physical page ids.

    The block table is a scalar-prefetch operand: the grid's last axis
    is the *logical* page index and the k/v/page_pos BlockSpec index
    maps dereference `tbl[b, lp]`, so the kernel streams only each
    request's mapped pages — the decode-read traffic is n_view * ps
    columns per request regardless of pool size P.

    Returns (acc (B, Hkv, G, Dv) f32, m (B, Hkv, G), l (B, Hkv, G));
    normalize with `merge_partials` (optionally merging a fresh-segment
    partial first, as tree verification does).
    """
    B, H, G, Dk = q.shape
    ps = k_pages.shape[2]
    Dv = v_pages.shape[3]
    nv = block_tables.shape[1]
    bq = max(8, G)

    q = _pad_to(q, bq, 2)
    qpos_rows = jnp.broadcast_to(q_pos.astype(jnp.int32)[:, None], (B, bq))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, H, nv),
        in_specs=[
            pl.BlockSpec((1, bq), lambda b, h, i, tbl: (b, 0)),
            pl.BlockSpec((1, ps), lambda b, h, i, tbl: (tbl[b, i], 0)),
            pl.BlockSpec((1, 1, bq, Dk), lambda b, h, i, tbl: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, ps, Dk),
                         lambda b, h, i, tbl: (tbl[b, i], h, 0, 0)),
            pl.BlockSpec((1, 1, ps, Dv),
                         lambda b, h, i, tbl: (tbl[b, i], h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, Dv), lambda b, h, i, tbl: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i, tbl: (b, h, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i, tbl: (b, h, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, Dv), jnp.float32),
        ],
    )
    kernel = _make_paged_kernel(scale=scale, window=window, nv=nv,
                                block_q=bq, dv=Dv)
    acc, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, H, bq, Dv), jnp.float32),
            jax.ShapeDtypeStruct((B, H, bq), jnp.float32),
            jax.ShapeDtypeStruct((B, H, bq), jnp.float32),
        ],
        interpret=interpret,
    )(block_tables.astype(jnp.int32), qpos_rows,
      page_pos.astype(jnp.int32), q, k_pages, v_pages)
    return acc[:, :, :G], m[:, :, :G], l[:, :, :G]
