"""jit'd wrapper: GQA flash-decode. The query token's GQA group becomes the
kernel's row dimension (classic flash-decoding layout), so the MXU sees a
(G x Dk) x (Dk x block_k) matmul per KV block instead of a GEMV."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.common import flash_attention_partial, merge_partials


@partial(jax.jit, static_argnames=("scale", "window", "interpret", "block_k"))
def decode_attention(q, k_cache, v_cache, cache_pos, q_pos, *, scale,
                     window=0, interpret=True, block_k=512):
    """Same signature/semantics as ref.decode_attention_ref (docs there)."""
    B, H, G, Dk = q.shape
    qpos_rows = jnp.broadcast_to(q_pos[:, None], (B, G))
    part = flash_attention_partial(
        q, k_cache, v_cache, qpos_rows, cache_pos, scale=scale, causal=True,
        window=window, block_q=max(8, G), block_k=block_k,
        interpret=interpret)
    return merge_partials([part])
