"""jit'd wrapper: GQA flash-decode. The query token's GQA group becomes the
kernel's row dimension (classic flash-decoding layout), so the MXU sees a
(G x Dk) x (Dk x block_k) matmul per KV block instead of a GEMV."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.common import flash_attention_partial, merge_partials
from repro.kernels.decode_attention.kernel import paged_flash_decode


@partial(jax.jit, static_argnames=("scale", "window", "interpret", "block_k"))
def decode_attention(q, k_cache, v_cache, cache_pos, q_pos, *, scale,
                     window=0, interpret=True, block_k=512):
    """Same signature/semantics as ref.decode_attention_ref (docs there)."""
    B, H, G, Dk = q.shape
    qpos_rows = jnp.broadcast_to(q_pos[:, None], (B, G))
    part = flash_attention_partial(
        q, k_cache, v_cache, qpos_rows, cache_pos, scale=scale, causal=True,
        window=window, block_q=max(8, G), block_k=block_k,
        interpret=interpret)
    return merge_partials([part])


@partial(jax.jit, static_argnames=("scale", "window", "interpret", "block_k"))
def decode_attention_slots(q, k_cache, v_cache, cache_pos, q_pos, slot_idx,
                           *, scale, window=0, interpret=True, block_k=512):
    """Slot-indexed flash decode: the KV cache holds a resident slot
    *pool* (batch axis S_pool >= B) and only rows `slot_idx` (B,) are
    attended — the read-side counterpart of the model's in-place
    slot-indexed cache writes. The gather stays inside the jitted
    program (XLA fuses it into the block streaming), so the Pallas
    kernel itself is unchanged and the fast path remains usable on the
    slot-resident serving cache.

    q: (B, Hkv, G, Dk); k_cache/v_cache: (S_pool, Hkv, C, Dk/Dv);
    cache_pos: (S_pool, C); q_pos: (B,); slot_idx: (B,) int32.
    """
    k = jnp.take(k_cache, slot_idx, axis=0)
    v = jnp.take(v_cache, slot_idx, axis=0)
    cp = jnp.take(cache_pos, slot_idx, axis=0)
    return decode_attention(q, k, v, cp, q_pos, scale=scale, window=window,
                            interpret=interpret, block_k=block_k)


@partial(jax.jit, static_argnames=("scale", "window", "interpret"))
def decode_attention_paged(q, k_pages, v_pages, page_pos, q_pos,
                           block_tables, *, scale, window=0, interpret=True):
    """Paged flash decode: the KV cache is a physical page *pool*
    (DESIGN.md §2.8) and each request reads only the pages named by its
    block table. Unlike `decode_attention_slots` (where XLA gathers the
    resident rows), the block table here is a scalar-prefetch operand
    and the Pallas grid walks it directly — the kernel never touches
    unmapped pages, so decode-read traffic scales with tokens *held*,
    not pool capacity.

    q: (B, Hkv, G, Dk); k_pages/v_pages: (P, Hkv, ps, Dk/Dv);
    page_pos: (P, ps) absolute positions (-1 = empty row, exact no-op);
    q_pos: (B,); block_tables: (B, n_view) int32 physical page ids
    (point unmapped entries at a NULL page whose page_pos is all -1).
    Returns (B, Hkv, G, Dv) f32, matching `decode_attention_paged_ref`.
    """
    part = paged_flash_decode(q, k_pages, v_pages, page_pos, q_pos,
                              block_tables, scale=scale, window=window,
                              interpret=interpret)
    return merge_partials([part])
