"""Synthetic multi-domain corpus generator.

Emulates the paper's five evaluation domains (PIQA physics / MedQA
medicine / FIQA finance / Alpaca instructions / OASST2 conversation) with
structurally distinct token sources: each domain is a random first-order
Markov chain over a disjoint-biased slice of the vocabulary with its own
temperature and loop structure. Drafters fine-tuned on one domain really
do draft that domain better — which is what exercises CoSine's routing
(Fig. 3a / Table 2 analogues are measured, not assumed).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence

import numpy as np

DOMAINS = ("piqa", "medqa", "fiqa", "alpaca", "oasst2")


@dataclass
class DomainSource:
    name: str
    trans: np.ndarray          # (V, V) row-stochastic transition matrix
    init: np.ndarray           # (V,) initial distribution

    def sample(self, rng: np.random.Generator, length: int) -> np.ndarray:
        out = np.empty(length, np.int32)
        out[0] = rng.choice(len(self.init), p=self.init)
        for i in range(1, length):
            out[i] = rng.choice(len(self.init), p=self.trans[out[i - 1]])
        return out


def _make_domain(name: str, vocab: int, seed: int,
                 sharpness: float = 8.0, support: int = 24) -> DomainSource:
    """Sparse, peaked Markov chain biased to a domain-specific vocab slice."""
    rng = np.random.default_rng(seed)
    k = DOMAINS.index(name) if name in DOMAINS else seed
    lo = (k * vocab // (len(DOMAINS) + 1)) % vocab
    hi = min(lo + max(vocab // 2, 8), vocab)
    trans = np.full((vocab, vocab), 1e-3)
    for v in range(vocab):
        nxt = rng.choice(np.arange(lo, hi), size=min(support, hi - lo),
                         replace=False)
        trans[v, nxt] += rng.dirichlet(np.ones(len(nxt))) * sharpness
    trans /= trans.sum(1, keepdims=True)
    init = np.zeros(vocab)
    init[lo:hi] = 1.0 / (hi - lo)
    return DomainSource(name, trans, init)


class SyntheticCorpus:
    def __init__(self, vocab: int, seed: int = 0,
                 domains: Sequence[str] = DOMAINS,
                 sharpness: float = 8.0, support: int = 24):
        self.vocab = vocab
        self.domains: Dict[str, DomainSource] = {
            d: _make_domain(d, vocab, seed * 31 + i, sharpness, support)
            for i, d in enumerate(domains)
        }
        self.rng = np.random.default_rng(seed)

    def sample(self, domain: str, length: int) -> np.ndarray:
        return self.domains[domain].sample(self.rng, length)

    def batch(self, domain: str, batch: int, length: int) -> np.ndarray:
        return np.stack([self.sample(domain, length) for _ in range(batch)])

    def mixed_batch(self, batch: int, length: int,
                    proportions: Optional[Dict[str, float]] = None):
        """Mixture batch + per-row domain labels (training the target)."""
        names = list(self.domains)
        p = (np.array([proportions[n] for n in names])
             if proportions else np.ones(len(names)) / len(names))
        p = p / p.sum()
        labels = [names[self.rng.choice(len(names), p=p)] for _ in range(batch)]
        rows = np.stack([self.sample(d, length) for d in labels])
        return rows, labels

    def prompts(self, n: int, length: int, seed: int = 0):
        """Evenly-mixed evaluation prompts with domain labels (paper §6.1:
        8192 prompts sampled across the five datasets).

        A pure function of (n, length, seed): sampling uses the local
        generator, NOT the corpus training stream (`self.rng`), so
        callers get identical prompts regardless of what ran before —
        the CI bench-regression gate relies on this (benchmark rows must
        not depend on run order)."""
        rng = np.random.default_rng(seed)
        names = list(self.domains)
        out = []
        for i in range(n):
            d = names[i % len(names)]
            out.append((self.domains[d].sample(rng, length), d))
        rng.shuffle(out)
        return out


def token_batches(corpus: SyntheticCorpus, domain: Optional[str],
                  batch: int, length: int, steps: int):
    """Iterator of (batch, length+1) training batches (inputs+shift labels)."""
    for _ in range(steps):
        if domain is None:
            rows, _ = corpus.mixed_batch(batch, length + 1)
        else:
            rows = corpus.batch(domain, batch, length + 1)
        yield rows
