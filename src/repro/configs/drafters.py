"""Drafter (SSM, "small speculative model") configs for the CoSine
speculation cluster.

The paper's drafters are LLaMA-68M / Qwen2.5-0.5B-class models fine-tuned
per domain (Table 2). `llama-68m` mirrors the LLaMA-68M drafter used with
the paper's LLaMA pair; `tiny-*` are CPU-trainable variants used by the
runnable examples and tests, where domain specialization is produced by
actually training each drafter on its own synthetic domain corpus.
"""
from repro.config import ModelConfig

# weight-only int8 variant of the same drafter (DESIGN.md §2.9): the
# checkpoint is calibrated and swapped at load; beside bf16 nodes this
# makes the pool genuinely heterogeneous in both pace and proposals
def int8_variant(cfg: ModelConfig) -> ModelConfig:
    """Per-node override: run this drafter with int8 weights."""
    return cfg.with_overrides(quant="int8",
                              name=cfg.name + "-int8")


LLAMA_68M = ModelConfig(
    name="llama-68m",
    family="dense",
    n_layers=2,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab=32000,
    rope_theta=10000.0,
)

LLAMA_68M_INT8 = int8_variant(LLAMA_68M)


def tiny_drafter(vocab: int, name: str = "tiny-drafter",
                 quant: str = "") -> ModelConfig:
    """CPU-trainable drafter in the same family as the target.

    `quant`: "" inherits the pool-wide `CoSineConfig.drafter_quant`
    default; "int8" pins this node to the weight-only int8 path.
    """
    return ModelConfig(
        name=name, family="dense", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=2, head_dim=32, d_ff=384, vocab=vocab,
        tie_embeddings=True, quant=quant,
    )


def tiny_target(vocab: int, name: str = "tiny-target") -> ModelConfig:
    """CPU-runnable verification target (bigger than the drafters)."""
    return ModelConfig(
        name=name, family="dense", n_layers=4, d_model=256,
        n_heads=8, n_kv_heads=4, head_dim=32, d_ff=768, vocab=vocab,
        tie_embeddings=True,
    )
