"""Drafter (SSM, "small speculative model") configs for the CoSine
speculation cluster.

The paper's drafters are LLaMA-68M / Qwen2.5-0.5B-class models fine-tuned
per domain (Table 2). `llama-68m` mirrors the LLaMA-68M drafter used with
the paper's LLaMA pair; `tiny-*` are CPU-trainable variants used by the
runnable examples and tests, where domain specialization is produced by
actually training each drafter on its own synthetic domain corpus.
"""
from repro.config import ModelConfig

LLAMA_68M = ModelConfig(
    name="llama-68m",
    family="dense",
    n_layers=2,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab=32000,
    rope_theta=10000.0,
)


def tiny_drafter(vocab: int, name: str = "tiny-drafter") -> ModelConfig:
    """CPU-trainable drafter in the same family as the target."""
    return ModelConfig(
        name=name, family="dense", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=2, head_dim=32, d_ff=384, vocab=vocab,
        tie_embeddings=True,
    )


def tiny_target(vocab: int, name: str = "tiny-target") -> ModelConfig:
    """CPU-runnable verification target (bigger than the drafters)."""
    return ModelConfig(
        name=name, family="dense", n_layers=4, d_model=256,
        n_heads=8, n_kv_heads=4, head_dim=32, d_ff=768, vocab=vocab,
        tie_embeddings=True,
    )
