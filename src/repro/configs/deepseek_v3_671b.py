"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8 experts, MTP.

61L d_model=7168 128H (MLA; assignment lists kv=128) moe d_ff=2048
vocab=129280, 256 routed experts top-8 [arXiv:2412.19437].
First 3 layers are dense FFN (width 18432, per the paper's own config);
the assignment's d_ff=2048 is the per-routed-expert width.
"""
from repro.config import ModelConfig, MoEConfig, MLAConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=18432,                  # dense layers 0..2 (DeepSeek-V3 paper value)
    vocab=129280,
    attention="mla",
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_routed=256, top_k=8, d_ff=2048, n_shared=1,
                  layer_offset=3, layer_period=1),
    mtp=True,
    rope_theta=10000.0,
    norm_eps=1e-6,
)
