"""whisper-small [audio] — encoder-decoder transformer backbone.

12L d_model=768 12H (kv=12) d_ff=3072 vocab=51865 [arXiv:2212.04356].
Conv/mel frontend is stubbed: input_specs() supplies precomputed frame
embeddings (batch, 1500, d_model). LayerNorm + GELU + learned positions,
per the Whisper architecture. max_position is widened beyond Whisper's 448
so the assigned 32k decoder shapes are expressible.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,                  # decoder layers
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab=51865,
    norm_type="layer",
    mlp_type="gelu",
    pos_embed="learned",
    max_position=65536,
    encoder_layers=12,
    encoder_seq=1500,             # 30 s of audio at 50 Hz after conv frontend
    n_frontend_tokens=1500,
    attention="full",
)
