"""llama-3.2-vision-11b [vlm] — language decoder with cross-attention image layers.

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256
[hf:meta-llama/Llama-3.2-11B-Vision]. Cross-attention at layers
3,8,...,38 (period 5, offset 3). Vision encoder + projector stubbed:
input_specs() supplies projected patch embeddings (batch, 1601, d_model).
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=128256,
    cross_attn_period=5,
    cross_attn_offset=3,
    n_frontend_tokens=1601,       # 1 tile x (40x40 patches + 1 cls)
    rope_theta=500000.0,
)
