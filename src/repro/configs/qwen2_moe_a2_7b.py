"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed experts, top-4.

24L d_model=2048 16H (kv=16) expert d_ff=1408 vocab=151936
[hf:Qwen/Qwen1.5-MoE-A2.7B]. Shared expert width 4*1408 = 5632.
"""
from repro.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=5632,                    # width used if a layer were dense (unused: all layers MoE)
    vocab=151936,
    qkv_bias=True,
    moe=MoEConfig(n_routed=60, top_k=4, d_ff=1408,
                  n_shared=4, shared_d_ff=5632,
                  layer_offset=0, layer_period=1),
    rope_theta=1000000.0,
)
