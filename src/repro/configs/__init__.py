"""Architecture registry: --arch <id> resolves here."""
from repro.config import ModelConfig, INPUT_SHAPES

from repro.configs.deepseek_v3_671b import CONFIG as _deepseek
from repro.configs.h2o_danube3_4b import CONFIG as _danube
from repro.configs.qwen3_32b import CONFIG as _qwen3
from repro.configs.qwen1_5_4b import CONFIG as _qwen15
from repro.configs.whisper_small import CONFIG as _whisper
from repro.configs.llama_3_2_vision_11b import CONFIG as _llama_vision
from repro.configs.mamba2_130m import CONFIG as _mamba2
from repro.configs.qwen2_moe_a2_7b import CONFIG as _qwen2_moe
from repro.configs.qwen2_0_5b import CONFIG as _qwen2_05
from repro.configs.jamba_v0_1_52b import CONFIG as _jamba

ARCHS: dict[str, ModelConfig] = {
    c.name: c for c in [
        _deepseek, _danube, _qwen3, _qwen15, _whisper,
        _llama_vision, _mamba2, _qwen2_moe, _qwen2_05, _jamba,
    ]
}

# long_500k policy (DESIGN.md §5): how each arch gets sub-quadratic decode.
#   native  — already sub-quadratic (SSM / hybrid / native SWA)
#   swa     — run with the sliding-window KV variant (window 8192)
#   skip    — N/A by design (enc-dec whisper)
LONG_CONTEXT_POLICY: dict[str, str] = {
    "deepseek-v3-671b": "swa",
    "h2o-danube3-4b": "native",
    "qwen3-32b": "swa",
    "qwen1.5-4b": "swa",
    "whisper-small": "skip",
    "llama-3.2-vision-11b": "swa",
    "mamba2-130m": "native",
    "qwen2-moe-a2.7b": "swa",
    "qwen2-0.5b": "swa",
    "jamba-v0.1-52b": "native",
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch]


def arch_shape_pairs() -> list[tuple[str, str]]:
    """All (arch, shape) combos the dry-run must cover; skips excluded."""
    pairs = []
    for arch in ARCHS:
        for shape in INPUT_SHAPES:
            if shape == "long_500k" and LONG_CONTEXT_POLICY[arch] == "skip":
                continue
            pairs.append((arch, shape))
    return pairs
