"""jamba-v0.1-52b [hybrid] — Mamba + attention 1:7 interleave, MoE 16e top-2.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536 [arXiv:2403.19887].
Attention at layer i where i % 8 == 4 (1 attention : 7 mamba);
MoE at odd layers (period 2, offset 1). No positional embedding (Jamba
relies on Mamba for position). The Mamba mixer here is the SSD (Mamba2)
formulation — noted adaptation in DESIGN.md.
"""
from repro.config import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=65536,
    attention="full",
    pos_embed="none",
    hybrid_attn_period=8,
    hybrid_attn_offset=4,
    moe=MoEConfig(n_routed=16, top_k=2, d_ff=14336,
                  layer_offset=1, layer_period=2),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64,
                  n_groups=1, chunk_size=128),
)
