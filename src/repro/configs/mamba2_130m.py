"""mamba2-130m [ssm] — attention-free SSD (state-space duality).

24L d_model=768 vocab=50280, ssm_state=128 [arXiv:2405.21060].
d_inner = 2*768 = 1536, head_dim=64 -> 24 SSD heads.
"""
from repro.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=24,                   # SSD heads (d_inner / head_dim)
    n_kv_heads=24,
    d_ff=0,                       # attention-free, no MLP block
    vocab=50280,
    attention="none",
    pos_embed="none",
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                  n_groups=1, chunk_size=128),
)
