"""Serving telemetry layer (DESIGN.md §2.6).

Three pieces, all dependency-free and deterministic:

  * `obs.trace`   — `Tracer`: per-request lifecycle + per-stage occupancy
                    spans built from instrumentation hooks in the serving
                    stack (engine / pipeline / cluster / admission).
  * `obs.metrics` — `MetricsRegistry`: counters, gauges and fixed-bucket
                    histograms — the single source behind `ServeStats`'
                    aggregates — plus the controller `DecisionLog`
                    (every λ/γ/admission decision with its inputs).
  * `obs.export`  — Chrome/Perfetto ``trace_event`` JSON export and a
                    flat metrics JSON (byte-identical across same-seed
                    runs), consumed by ``python -m repro.obs.summarize``.

The span schema is the contract the future async wall-clock serve loop
must emit, so its measured overlap can be diffed against the
discrete-event executor's prediction (ROADMAP headline item).
"""
from repro.obs.metrics import DecisionLog, MetricsRegistry
from repro.obs.trace import Span, Tracer

__all__ = ["DecisionLog", "MetricsRegistry", "Span", "Tracer"]
