"""Lightweight metrics registry + controller decision log (DESIGN.md §2.6).

The registry is the *single source* for the serving aggregates: engine,
executor and cluster increment counters / set gauges / observe histograms
here, and `ServeStats`' properties (plus the benchmark columns) read them
back — no ad-hoc `total_x += ...` fields scattered across modules.

Naming convention: dotted ``subsystem.metric[_unit]`` names with optional
labels, e.g. ``verify.busy_ms``, ``serve.committed_tokens``,
``draft.node_tokens{node=3}``. Everything is plain Python floats/ints —
no deps, no locks (the serving loop is single-threaded), and
`to_dict()` is deterministically ordered so a metrics JSON export is
byte-identical across same-seed runs.

`DecisionLog` records why the controllers changed anything: every
λ-multiplier update, per-request `slo_gamma` trim, `balance_gamma` cap
and admission shed/queue/preempt verdict is appended with its inputs, so
feedback behaviour is auditable and testable (tests/test_obs.py checks
the logged values against what the scheduler actually applied).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Tuple

# fixed default buckets (ms-scale quantities dominate; the top bucket is
# +inf by construction — `Histogram.counts` has len(buckets) + 1 cells)
DEFAULT_BUCKETS = (1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0,
                   500.0, 1000.0, 2000.0, 5000.0, 10000.0)


def _label_key(labels: Dict[str, object]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _fmt_name(name: str, labels: Tuple[Tuple[str, str], ...]) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in labels)
    return f"{name}{{{inner}}}"


@dataclass
class Counter:
    value: float = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += v


@dataclass
class Gauge:
    value: float = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


@dataclass
class Histogram:
    """Fixed-bucket histogram: counts[i] = observations <= buckets[i],
    counts[-1] = overflow; plus sum/count for means."""
    buckets: Tuple[float, ...] = DEFAULT_BUCKETS
    counts: List[int] = field(default_factory=list)
    sum: float = 0.0
    count: int = 0

    def __post_init__(self):
        if not self.counts:
            self.counts = [0] * (len(self.buckets) + 1)

    def observe(self, v: float) -> None:
        self.sum += v
        self.count += 1
        for i, ub in enumerate(self.buckets):
            if v <= ub:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0


@dataclass(frozen=True)
class Decision:
    """One controller decision: what changed, when, and the inputs it was
    computed from. `fields` is a sorted (key, value) tuple so the entry
    hashes/compares deterministically and serializes canonically."""
    t_ms: float
    seq: int
    kind: str                    # lam | slo_gamma | balance_gamma |
    #                              gamma_feedback | plan | admission
    fields: Tuple[Tuple[str, object], ...]

    def get(self, key: str, default=None):
        for k, v in self.fields:
            if k == key:
                return v
        return default

    def to_dict(self) -> dict:
        d = {"t_ms": self.t_ms, "seq": self.seq, "kind": self.kind}
        d.update({k: v for k, v in self.fields})
        return d


class DecisionLog:
    def __init__(self, max_entries: int = 0):
        self.max_entries = int(max_entries)
        self.entries: Deque[Decision] = deque(
            maxlen=self.max_entries if self.max_entries > 0 else None)
        self._seq = 0
        self.n_dropped = 0

    def record(self, t_ms: float, kind: str, **fields) -> Decision:
        if self.max_entries > 0 and len(self.entries) == self.max_entries:
            self.n_dropped += 1
        d = Decision(float(t_ms), self._seq, kind,
                     tuple(sorted(fields.items())))
        self._seq += 1
        self.entries.append(d)
        return d

    def by_kind(self, kind: str) -> List[Decision]:
        return [d for d in self.entries if d.kind == kind]

    def __len__(self):
        return len(self.entries)


class MetricsRegistry:
    """Get-or-create registry of counters/gauges/histograms keyed by
    (name, sorted labels), plus the controller decision log."""

    def __init__(self, max_decisions: int = 0):
        self._counters: Dict[tuple, Counter] = {}
        self._gauges: Dict[tuple, Gauge] = {}
        self._histograms: Dict[tuple, Histogram] = {}
        self.decisions = DecisionLog(max_entries=max_decisions)

    # ------------------------------------------------------------- access
    def counter(self, name: str, **labels) -> Counter:
        key = (name, _label_key(labels))
        c = self._counters.get(key)
        if c is None:
            c = self._counters[key] = Counter()
        return c

    def gauge(self, name: str, **labels) -> Gauge:
        key = (name, _label_key(labels))
        g = self._gauges.get(key)
        if g is None:
            g = self._gauges[key] = Gauge()
        return g

    def histogram(self, name: str, buckets: Tuple[float, ...] = DEFAULT_BUCKETS,
                  **labels) -> Histogram:
        key = (name, _label_key(labels))
        h = self._histograms.get(key)
        if h is None:
            h = self._histograms[key] = Histogram(buckets=buckets)
        return h

    # ---------------------------------------------------------- shorthand
    def inc(self, name: str, v: float = 1.0, **labels) -> None:
        self.counter(name, **labels).inc(v)

    def set_gauge(self, name: str, v: float, **labels) -> None:
        self.gauge(name, **labels).set(v)

    def observe(self, name: str, v: float, **labels) -> None:
        self.histogram(name, **labels).observe(v)

    def value(self, name: str, default: float = 0.0, **labels) -> float:
        """Current counter (or gauge) value; `default` when absent."""
        key = (name, _label_key(labels))
        if key in self._counters:
            return self._counters[key].value
        if key in self._gauges:
            return self._gauges[key].value
        return default

    def label_values(self, name: str, label: str) -> List[str]:
        """Distinct values a label takes for `name` (sorted)."""
        out = set()
        for (n, labels) in list(self._counters) + list(self._gauges):
            if n != name:
                continue
            for k, v in labels:
                if k == label:
                    out.add(v)
        return sorted(out)

    # ------------------------------------------------------------- export
    def to_dict(self) -> dict:
        """Deterministically-ordered flat dict for the metrics JSON."""
        counters = {_fmt_name(n, k): c.value
                    for (n, k), c in sorted(self._counters.items())}
        gauges = {_fmt_name(n, k): g.value
                  for (n, k), g in sorted(self._gauges.items())}
        hists = {}
        for (n, k), h in sorted(self._histograms.items()):
            hists[_fmt_name(n, k)] = {
                "buckets": list(h.buckets), "counts": list(h.counts),
                "sum": h.sum, "count": h.count}
        return {
            "counters": counters, "gauges": gauges, "histograms": hists,
            "decisions": [d.to_dict() for d in self.decisions.entries],
            "decisions_dropped": self.decisions.n_dropped,
        }
