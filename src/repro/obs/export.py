"""Chrome/Perfetto ``trace_event`` + metrics JSON export (DESIGN.md §2.6).

`build_trace` turns a `Tracer`'s spans into the Chrome trace-event JSON
format (one thread per track: the verify stage, each drafter node, the
cluster fusion/transit track, and one per request) loadable in Perfetto
or chrome://tracing. Stage spans covering multiple requests are also
*projected* onto each covered request's track, so a request's row shows
its full waterfall (prefill → draft → verify → commit) without clicking
through the stage rows.

Every event embeds its logical ``track`` in ``args`` (plus the source
stage for projected copies), so downstream consumers — the summarizer
and `check_regression.py`'s busy/idle gate — parse the flat event list
without cross-referencing thread metadata.

Determinism contract: all timestamps come from the simulated stage
clocks, ids from monotone sequence counters, serialization is
`sort_keys=True` with fixed rounding — two same-seed runs export
byte-identical files (tested in tests/test_obs.py). No wall-clock
anywhere.
"""
from __future__ import annotations

import json
from typing import Dict, List

from repro.obs.trace import LIFECYCLE, Span, Tracer

PID = 1
PROCESS_NAME = "repro-serving"


def _track_key(track: str):
    """Deterministic display order: verify, draft nodes, cluster, then
    request tracks by rid."""
    if track == "verify":
        return (0, 0, track)
    if track == "draft":
        return (1, -1, track)
    if track.startswith("draft"):
        try:
            return (1, int(track[5:]), track)
        except ValueError:
            return (1, 1 << 30, track)
    if track == "cluster":
        return (2, 0, track)
    if track.startswith("req"):
        try:
            return (3, int(track[3:]), track)
        except ValueError:
            return (3, 1 << 30, track)
    return (4, 0, track)


def _ts(t_ms: float) -> float:
    """trace_event timestamps are microseconds; fixed rounding keeps the
    serialization byte-stable."""
    return round(t_ms * 1000.0, 3)


def _span_args(s: Span, track: str, stage: str = "") -> dict:
    args: dict = {"track": track, "cohort": s.cohort}
    if stage:
        args["stage"] = stage            # projected copy: source track
    if s.rid >= 0:
        args["rid"] = s.rid
    if s.rids:
        args["rids"] = list(s.rids)
    for k, v in s.args:
        args[k] = v
    return args


def _event(s: Span, tid: int, track: str, stage: str = "") -> dict:
    ev = {
        "name": s.name, "cat": s.cat, "pid": PID, "tid": tid,
        "ts": _ts(s.t0_ms), "args": _span_args(s, track, stage),
    }
    if s.is_instant:
        ev["ph"] = "i"
        ev["s"] = "t"
    else:
        ev["ph"] = "X"
        ev["dur"] = _ts(s.t1_ms) - _ts(s.t0_ms)
    return ev


def build_trace(tracer: Tracer) -> dict:
    """Chrome trace-event dict: metadata + one event per span + a
    projected copy of every multi-request stage span on each covered
    request's track (the per-request waterfall)."""
    tracks = {s.track for s in tracer.spans}
    for s in tracer.spans:
        for rid in s.rids:
            tracks.add(f"req{rid}")
    ordered = sorted(tracks, key=_track_key)
    tid_of: Dict[str, int] = {t: i + 1 for i, t in enumerate(ordered)}

    events: List[dict] = [{
        "name": "process_name", "ph": "M", "pid": PID, "tid": 0,
        "args": {"name": PROCESS_NAME}}]
    for t in ordered:
        events.append({"name": "thread_name", "ph": "M", "pid": PID,
                       "tid": tid_of[t], "args": {"name": t}})
        events.append({"name": "thread_sort_index", "ph": "M", "pid": PID,
                       "tid": tid_of[t],
                       "args": {"sort_index": tid_of[t]}})
    for s in tracer.spans:
        events.append(_event(s, tid_of[s.track], s.track))
        if s.cat != LIFECYCLE:
            for rid in s.rids:
                rt = f"req{rid}"
                events.append(_event(s, tid_of[rt], rt, stage=s.track))
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"spans_dropped": tracer.n_dropped}}


def export_trace(tracer: Tracer, path: str) -> str:
    with open(path, "w") as f:
        json.dump(build_trace(tracer), f, sort_keys=True)
        f.write("\n")
    return path


def build_metrics(engine) -> dict:
    """Flat metrics JSON for one engine run: the registry contents plus
    the telemetry drop counters (satellite: ring-bounded logs surface
    what they dropped)."""
    m = engine.metrics
    m.set_gauge("obs.spans_dropped", engine.tracer.n_dropped)
    if engine.executor is not None:
        m.set_gauge("obs.events_dropped", engine.executor.log.n_dropped)
    return m.to_dict()


def export_metrics(engine, path: str) -> str:
    with open(path, "w") as f:
        json.dump(build_metrics(engine), f, sort_keys=True)
        f.write("\n")
    return path


def export_engine_trace(engine, path: str) -> str:
    """Convenience: trace JSON next to a sibling ``*.metrics.json``."""
    export_trace(engine.tracer, path)
    mpath = (path[:-5] if path.endswith(".json") else path) \
        + ".metrics.json"
    export_metrics(engine, mpath)
    return path
