"""Per-request / per-stage span tracer (DESIGN.md §2.6).

A `Span` is one closed interval on one *track* of the serving timeline:

  * stage tracks  — ``verify``, ``draft{i}`` (one per drafter node),
    ``draft`` (the coupled baselines' aggregate cluster), ``cluster``
    (fusion/transit activity that is not node occupancy). Work spans on a
    serial stage track tile without overlap; measured idle gaps are
    emitted as explicit ``bubble`` spans carrying their cause, so the
    stage's busy/idle totals are recoverable from the trace alone (and
    must match `ServeStats` — CI gates the drift).
  * request tracks — ``req{rid}``: lifecycle instants (``arrival``,
    ``shed``, ``preempt``, ``readmit``, ``commit``, ``first_token``,
    ``complete``) plus, at export time, every stage span whose `rids`
    include the request — the per-request waterfall.

Span identity is deterministic: `seq` is a global monotone counter in
host execution order (single-threaded serving loop), and the exported id
is derived from (track, cohort, rid, name, seq); all times come from the
simulated stage clocks. Two same-seed runs therefore produce
byte-identical exports (tested), which is the validation contract the
future async wall-clock loop must satisfy against this executor.

Memory is bounded by `max_spans` (a ring: oldest spans drop, the drop
count is surfaced in the metrics export); with the cap unhit the trace
is complete and determinism tests are unaffected.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Deque, List, Optional, Tuple

# span categories
STAGE = "stage"          # serial-resource occupancy (verify / draft nodes)
CLUSTER = "cluster"      # cluster-level activity (fuse, transit)
LIFECYCLE = "lifecycle"  # per-request state transitions (instants)


@dataclass(frozen=True)
class Span:
    seq: int
    name: str
    cat: str                     # STAGE | CLUSTER | LIFECYCLE
    track: str                   # "verify" | "draft{i}" | "cluster" | "req{rid}"
    t0_ms: float
    t1_ms: float                 # == t0_ms for instants
    rid: int = -1                # owning request (lifecycle spans)
    cohort: int = -1             # cohort sequence number (-1 = none)
    rids: Tuple[int, ...] = ()   # requests a stage span covers
    args: Tuple[Tuple[str, object], ...] = ()

    @property
    def dur_ms(self) -> float:
        return self.t1_ms - self.t0_ms

    @property
    def is_instant(self) -> bool:
        return self.t1_ms == self.t0_ms

    def span_id(self) -> str:
        """Deterministic id: rid + cohort seq + name + global order."""
        return f"{self.track}/c{self.cohort}/r{self.rid}/{self.name}/{self.seq}"

    def get(self, key: str, default=None):
        for k, v in self.args:
            if k == key:
                return v
        return default


class Tracer:
    def __init__(self, enabled: bool = True, max_spans: int = 0):
        self.enabled = enabled
        self.max_spans = int(max_spans)
        self.spans: Deque[Span] = deque(
            maxlen=self.max_spans if self.max_spans > 0 else None)
        self._seq = 0
        self.n_dropped = 0

    def span(self, name: str, cat: str, track: str, t0_ms: float,
             t1_ms: float, rid: int = -1, cohort: int = -1,
             rids: Tuple[int, ...] = (), **args) -> Optional[Span]:
        if not self.enabled:
            return None
        if self.max_spans > 0 and len(self.spans) == self.max_spans:
            self.n_dropped += 1
        s = Span(self._seq, name, cat, track, float(t0_ms), float(t1_ms),
                 int(rid), int(cohort), tuple(int(r) for r in rids),
                 tuple(sorted(args.items())))
        self._seq += 1
        self.spans.append(s)
        return s

    def instant(self, name: str, cat: str, track: str, t_ms: float,
                rid: int = -1, cohort: int = -1,
                rids: Tuple[int, ...] = (), **args) -> Optional[Span]:
        return self.span(name, cat, track, t_ms, t_ms, rid=rid,
                         cohort=cohort, rids=rids, **args)

    def mark(self, name: str, rid: int, t_ms: float, cohort: int = -1,
             **args) -> Optional[Span]:
        """Lifecycle instant on the request's own track."""
        return self.instant(name, LIFECYCLE, f"req{rid}", t_ms, rid=rid,
                            cohort=cohort, **args)

    # --------------------------------------------------------------- views
    def by_track(self, track: str) -> List[Span]:
        return [s for s in self.spans if s.track == track]

    def stage_tracks(self) -> List[str]:
        return sorted({s.track for s in self.spans if s.cat == STAGE})

    def stage_totals(self, track: str) -> Tuple[float, float]:
        """(busy_ms, idle_ms) of one serial stage track, from the trace
        alone: work spans are busy, `bubble` spans are measured idle."""
        busy = idle = 0.0
        for s in self.by_track(track):
            if s.cat != STAGE or s.is_instant:
                continue
            if s.name == "bubble":
                idle += s.dur_ms
            else:
                busy += s.dur_ms
        return busy, idle
