"""CLI trace summarizer: ``python -m repro.obs.summarize trace.json``.

Reads a trace exported by `repro.obs.export` and prints

  * per-stage busy/idle/utilization totals (recomputed from the spans
    alone — the same accounting `check_regression.py` gates against the
    benchmark's vutil column),
  * the top pipeline-bubble causes by total stalled time,
  * a per-request waterfall (first N requests): every lifecycle instant
    and stage span on the request's track, in time order.

Works on the flat event list via the embedded ``args.track`` /
``args.stage`` fields — no thread-metadata cross-referencing.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict
from typing import Dict, List, Tuple


def _is_projected(ev: dict) -> bool:
    """Projected per-request copies carry their source stage track."""
    return "stage" in ev.get("args", {})


def stage_totals(events: List[dict]) -> Dict[str, Tuple[float, float]]:
    """track -> (busy_us, idle_us) over the serial stage tracks, from
    the trace alone: work spans are busy, ``bubble`` spans are idle.
    Projected request-track copies are excluded (they would double
    count), as is the cluster track (transit overlaps node work)."""
    out: Dict[str, List[float]] = defaultdict(lambda: [0.0, 0.0])
    for ev in events:
        if ev.get("ph") != "X" or ev.get("cat") != "stage" \
                or _is_projected(ev):
            continue
        track = ev["args"]["track"]
        out[track][ev["name"] == "bubble"] += ev.get("dur", 0.0)
    return {t: (b, i) for t, (b, i) in out.items()}


def bubble_causes(events: List[dict]) -> List[Tuple[str, float, int]]:
    """(cause, total_us, count) for every bubble span, worst first."""
    acc: Dict[str, List[float]] = defaultdict(lambda: [0.0, 0])
    for ev in events:
        if ev.get("ph") != "X" or ev.get("name") != "bubble" \
                or _is_projected(ev):
            continue
        cause = ev["args"].get("cause", "unknown")
        acc[cause][0] += ev.get("dur", 0.0)
        acc[cause][1] += 1
    return sorted(((c, v[0], int(v[1])) for c, v in acc.items()),
                  key=lambda x: (-x[1], x[0]))


def request_tracks(events: List[dict]) -> Dict[int, List[dict]]:
    """rid -> that request's events (lifecycle + projected stage spans),
    time-ordered."""
    out: Dict[int, List[dict]] = defaultdict(list)
    for ev in events:
        track = ev.get("args", {}).get("track", "")
        if ev.get("ph") in ("X", "i") and track.startswith("req"):
            try:
                rid = int(track[3:])
            except ValueError:
                continue
            out[rid].append(ev)
    for evs in out.values():
        evs.sort(key=lambda e: (e["ts"], e.get("dur", 0.0), e["name"]))
    return dict(sorted(out.items()))


def summarize(trace: dict, n_requests: int = 4, n_causes: int = 5,
              out=sys.stdout) -> None:
    events = trace["traceEvents"]
    w = out.write

    w("== stage occupancy ==\n")
    totals = stage_totals(events)
    for track in sorted(totals):
        busy, idle = totals[track]
        util = busy / max(busy + idle, 1e-9)
        w(f"  {track:<10s} busy {busy / 1000.0:10.2f} ms   "
          f"idle {idle / 1000.0:10.2f} ms   util {util:6.1%}\n")

    causes = bubble_causes(events)
    w("\n== top bubble causes ==\n")
    if not causes:
        w("  (no pipeline bubbles)\n")
    for cause, us, n in causes[:n_causes]:
        w(f"  {cause:<14s} {us / 1000.0:10.2f} ms over {n} bubbles\n")

    w("\n== per-request waterfall ==\n")
    tracks = request_tracks(events)
    for rid, evs in list(tracks.items())[:n_requests]:
        w(f"  req {rid}:\n")
        for ev in evs:
            t0 = ev["ts"] / 1000.0
            if ev["ph"] == "i":
                w(f"    {t0:10.2f} ms             * {ev['name']}\n")
            else:
                t1 = (ev["ts"] + ev.get("dur", 0.0)) / 1000.0
                stage = ev["args"].get("stage", "")
                w(f"    {t0:10.2f} ms -> {t1:10.2f} ms  {ev['name']}"
                  f"{f' [{stage}]' if stage else ''}\n")
    if len(tracks) > n_requests:
        w(f"  ... {len(tracks) - n_requests} more requests\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Summarize a repro.obs trace JSON")
    ap.add_argument("trace", help="path to a trace exported with --trace")
    ap.add_argument("--requests", type=int, default=4,
                    help="waterfalls to print (default 4)")
    ap.add_argument("--causes", type=int, default=5,
                    help="bubble causes to print (default 5)")
    args = ap.parse_args(argv)
    with open(args.trace) as f:
        trace = json.load(f)
    summarize(trace, n_requests=args.requests, n_causes=args.causes)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
