"""Msgpack tensor checkpointing (sharding-aware on restore).

Format: one .msgpack file holding {flat_key: {dtype, shape, raw bytes}} +
a small json-able meta dict. Flat keys are '/'-joined pytree paths, so any
nested dict/tuple/list params tree round-trips.
"""
from __future__ import annotations

import os
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        tag = "T" if isinstance(tree, tuple) else "L"
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}__{tag}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: Dict[str, Any]):
    root: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def rebuild(node):
        if not isinstance(node, dict):
            return node
        keys = list(node.keys())
        if keys and all(k.startswith("__T") or k.startswith("__L") for k in keys):
            seq = [rebuild(node[k]) for k in sorted(
                keys, key=lambda s: int(s[3:]))]
            return tuple(seq) if keys[0].startswith("__T") else seq
        return {k: rebuild(v) for k, v in node.items()}

    return rebuild(root)


def save_checkpoint(path: str, params, meta: Optional[dict] = None):
    flat = _flatten(params)
    payload = {"__meta__": meta or {}}
    for k, v in flat.items():
        arr = np.asarray(v)
        payload[k] = {"dtype": str(arr.dtype), "shape": list(arr.shape),
                      "data": arr.tobytes()}
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))


def load_checkpoint(path: str, shardings=None, quantize: Optional[str] = None):
    """Restore params; if `shardings` (matching pytree of NamedSharding)
    is given, each tensor is device_put with its sharding on load.

    quantize="int8" is the calibrate-then-swap hook (DESIGN.md §2.9):
    the trained f32 checkpoint is loaded, per-output-channel symmetric
    int8 scales are calibrated from the weights themselves, and the
    dense/embedding leaves are swapped for ``{"w8", "scale"}`` dicts
    before the params are returned. An already-quantized checkpoint
    (int8 leaves round-trip through the msgpack format unchanged)
    passes through idempotently.
    """
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    meta = payload.pop("__meta__", {})
    flat = {}
    for k, spec in payload.items():
        arr = np.frombuffer(spec["data"], dtype=spec["dtype"]).reshape(
            spec["shape"])
        flat[k] = jnp.asarray(arr)
    params = _unflatten(flat)
    if quantize == "int8":
        from repro.models.quantize import quantize_params
        params = quantize_params(params)
    elif quantize not in (None, "", "none"):
        raise ValueError(f"unknown quantize mode {quantize!r}")
    if shardings is not None:
        params = jax.tree.map(
            lambda x, s: jax.device_put(x, s), params, shardings)
    return params, meta
