"""Training-substrate driver: pretrain a target on the domain mixture and
fine-tune one drafter per domain (the paper's knowledge-distillation setup,
reproduced with real gradient descent), save checkpoints, then measure the
Table-2-style acceptance matrix.

  PYTHONPATH=src python examples/train_drafters.py --steps 150
"""
import argparse
import os


from repro.checkpoint.store import save_checkpoint
from repro.config import CoSineConfig
from repro.configs.drafters import tiny_drafter, tiny_target
from repro.data.synthetic import DOMAINS, SyntheticCorpus
from repro.launch.train import train_model
from repro.serving.engine import SpeculativeEngine

VOCAB = 96


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--out", type=str, default="checkpoints")
    args = ap.parse_args()

    corpus = SyntheticCorpus(VOCAB, seed=0, sharpness=60.0, support=6)
    tcfg, dcfg = tiny_target(VOCAB), tiny_drafter(VOCAB)

    tparams, _ = train_model(tcfg, corpus, None, args.steps * 2, batch=16,
                             seq=64)
    os.makedirs(args.out, exist_ok=True)
    save_checkpoint(os.path.join(args.out, "target.msgpack"), tparams)

    drafters = []
    for i, dom in enumerate(DOMAINS):
        dp, losses = train_model(dcfg, corpus, dom, args.steps, batch=16,
                                 seq=64, seed=i + 1)
        save_checkpoint(os.path.join(args.out, f"drafter_{dom}.msgpack"), dp)
        drafters.append((dcfg, dp, dom))
        print(f"drafter[{dom}] final loss {losses[-1]:.3f}")

    print("\nacceptance matrix (tokens/iteration, drafter x domain):")
    print(f"{'':>8}" + "".join(f"{d:>9}" for d in DOMAINS))
    for dcfg_, dparams, ddom in drafters:
        row = []
        for dom in DOMAINS:
            cos = CoSineConfig(n_drafters=1, draft_len=5,
                               drafters_per_request=1, tree_width=0)
            eng = SpeculativeEngine((tcfg, tparams), [(dcfg_, dparams, ddom)],
                                    cos, strategy="vanilla", max_len=512)
            pr = [pd for pd in corpus.prompts(10, 16, seed=21)
                  if pd[1] == dom][:2]
            for p, d in pr:
                eng.submit(p, max_new_tokens=24, domain=d)
            st = eng.run()
            iters = sum(r.n_iterations for r in eng.pool.completed)
            row.append(st.total_committed / max(iters, 1))
        print(f"{ddom:>8}" + "".join(f"{v:>9.2f}" for v in row))


if __name__ == "__main__":
    main()
