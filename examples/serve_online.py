"""End-to-end online serving driver: a CoSine deployment handling a
Poisson request stream across all five domains, with continuous batching,
adaptive routing, token fusion, and the Alg. 2 scheduler — then the same
stream through each baseline for comparison.

  PYTHONPATH=src python examples/serve_online.py [--requests 12] [--mode volatile]

With --trace [DIR], the cosine run's telemetry (DESIGN.md §2.6) is
exported as DIR/serve_online_cosine.json — a Perfetto-loadable trace
(load it at https://ui.perfetto.dev or chrome://tracing) plus a sibling
.metrics.json with the counters and the controller decision log.
Summarize it in the terminal with:

  PYTHONPATH=src python -m repro.obs.summarize DIR/serve_online_cosine.json
"""
import argparse
import os
import sys

import numpy as np

# resolve the bench helpers relative to this file so the example runs
# from any cwd (repo root is needed for `benchmarks.*`, the package dir
# for the fixture-building `common` module)
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "benchmarks"))
sys.path.insert(0, _ROOT)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--mode", choices=["low", "high", "volatile"],
                    default="volatile")
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--trace", type=str, nargs="?", const="traces",
                    default=None, metavar="DIR",
                    help="export the cosine run's Perfetto trace + "
                         "metrics JSON into DIR (default ./traces)")
    args = ap.parse_args()

    from common import build_fixture
    from benchmarks.online_serving import make_arrivals

    print("== loading fixture (trains + caches on first run) ==")
    fx = build_fixture(verbose=True)

    arrivals = make_arrivals(args.mode, args.requests, seed=5)
    prompts = fx.corpus.prompts(args.requests, 16, seed=13)

    print(f"== {args.requests} requests, {args.mode} arrivals ==")
    header = f"{'strategy':<10} {'ms/token':>9} {'p95':>8} {'tok/s':>8} " \
             f"{'acc/iter':>9}"
    print(header)
    for strategy in ("ar", "vanilla", "specinfer", "pipeinfer", "cosine"):
        eng = fx.engine(strategy)
        for (p, dom), t in zip(prompts, arrivals):
            eng.submit(p, max_new_tokens=args.max_new, domain=dom,
                       arrival_ms=float(t))
        stats = eng.run()
        lat = [(r.finish_ms - r.arrival_ms) / max(len(r.generated), 1)
               for r in eng.pool.completed]
        print(f"{strategy:<10} {np.mean(lat):>9.1f} "
              f"{np.percentile(lat, 95):>8.1f} "
              f"{stats.throughput_tps:>8.1f} {stats.mean_acceptance:>9.2f}")
        if args.trace and strategy == "cosine":
            from repro.obs.export import export_engine_trace
            os.makedirs(args.trace, exist_ok=True)
            path = os.path.join(args.trace, "serve_online_cosine.json")
            export_engine_trace(eng, path)
            print(f"  trace -> {path} (+ sibling .metrics.json)")

    print("\nper-domain routing learned by CoSine (request 0's M vector):")


if __name__ == "__main__":
    main()
