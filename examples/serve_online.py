"""End-to-end online serving driver: a CoSine deployment handling a
Poisson request stream across all five domains, with continuous batching,
adaptive routing, token fusion, and the Alg. 2 scheduler — then the same
stream through each baseline for comparison.

  PYTHONPATH=src python examples/serve_online.py [--requests 12] [--mode volatile]

Per-request completions are surfaced *as tokens commit* (the engine's
`on_commit` streaming hook), not after `run()` returns — watch the
`done` lines interleave with the serving iterations.

With `--backend async` the comparison table is replaced by a real
asyncio front-end on the wall-clock `AsyncJaxBackend` (DESIGN.md §2.7):
the engine loop runs in a thread, tokens stream into per-request
asyncio queues as they commit, and each request's consumer prints its
stream incrementally — the quickstart for the ROADMAP's "real async
serving loop" item.

With --trace [DIR], the cosine run's telemetry (DESIGN.md §2.6) is
exported as DIR/serve_online_cosine.json — a Perfetto-loadable trace
(load it at https://ui.perfetto.dev or chrome://tracing) plus a sibling
.metrics.json with the counters and the controller decision log.
Summarize it in the terminal with:

  PYTHONPATH=src python -m repro.obs.summarize DIR/serve_online_cosine.json
"""
import argparse
import asyncio
import os
import sys

import numpy as np

# resolve the bench helpers relative to this file so the example runs
# from any cwd (repo root is needed for `benchmarks.*`, the package dir
# for the fixture-building `common` module)
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "benchmarks"))
sys.path.insert(0, _ROOT)


def _attach_completion_printer(eng):
    """Print each request the moment its last token commits (streaming
    surface of the non-async path; same hook the async front-end uses)."""
    def on_commit(req, toks, now_ms):
        if req.done:
            print(f"    [t={now_ms:8.1f}ms] rid={req.rid} done "
                  f"({len(req.generated)} tokens)")
    eng.on_commit = on_commit


def run_sync(fx, args, arrivals, prompts):
    print(f"== {args.requests} requests, {args.mode} arrivals ==")
    header = f"{'strategy':<10} {'ms/token':>9} {'p95':>8} {'tok/s':>8} " \
             f"{'acc/iter':>9}"
    print(header)
    for strategy in ("ar", "vanilla", "specinfer", "pipeinfer", "cosine"):
        eng = fx.engine(strategy)
        if args.stream:
            _attach_completion_printer(eng)
        for (p, dom), t in zip(prompts, arrivals):
            eng.submit(p, max_new_tokens=args.max_new, domain=dom,
                       arrival_ms=float(t))
        stats = eng.run()
        lat = [(r.finish_ms - r.arrival_ms) / max(len(r.generated), 1)
               for r in eng.pool.completed]
        print(f"{strategy:<10} {np.mean(lat):>9.1f} "
              f"{np.percentile(lat, 95):>8.1f} "
              f"{stats.throughput_tps:>8.1f} {stats.mean_acceptance:>9.2f}")
        if args.trace and strategy == "cosine":
            from repro.obs.export import export_engine_trace
            os.makedirs(args.trace, exist_ok=True)
            path = os.path.join(args.trace, "serve_online_cosine.json")
            export_engine_trace(eng, path)
            print(f"  trace -> {path} (+ sibling .metrics.json)")

    print("\nper-domain routing learned by CoSine (request 0's M vector):")


async def run_async(fx, args, arrivals, prompts):
    """Asyncio front-end on the wall-clock backend: engine loop in a
    worker thread, per-request token streams as asyncio queues fed from
    the engine's on_commit hook."""
    loop = asyncio.get_running_loop()
    eng = fx.engine(args.strategy, backend="async")
    queues = {}

    def on_commit(req, toks, now_ms):
        q = queues.get(req.rid)
        if q is not None:
            loop.call_soon_threadsafe(q.put_nowait, (list(toks), req.done))

    eng.on_commit = on_commit

    async def consume(rid, dom):
        got, q = [], queues[rid]
        while True:
            toks, done = await q.get()
            got.extend(toks)
            print(f"  rid={rid} [{dom:>9}] +{len(toks):2d} tokens "
                  f"({len(got):3d} total)" + ("  <done>" if done else ""))
            if done:
                return got

    print(f"== async: {args.requests} requests, {args.strategy}, "
          f"wall-clock backend ==")
    for (p, dom), t in zip(prompts, arrivals):
        r = eng.submit(p, max_new_tokens=args.max_new, domain=dom,
                       arrival_ms=float(t))
        queues[r.rid] = asyncio.Queue()
    consumers = [asyncio.create_task(consume(r.rid, r.domain or "-"))
                 for r in eng.pool.pending(float("inf"))]
    stats = await loop.run_in_executor(None, eng.run)
    await asyncio.gather(*consumers)
    eng.backend.shutdown()

    done = eng.pool.completed
    lat = [(r.finish_ms - r.arrival_ms) / max(len(r.generated), 1)
           for r in done]
    print(f"\n{len(done)} completed | ms/token {np.mean(lat):.1f} "
          f"(wall) | p95 {np.percentile(lat, 95):.1f} | "
          f"verifier util {stats.verifier_utilization:.2f} | "
          f"{stats.total_committed} tokens in {stats.sim_ms:.0f}ms wall")
    if args.trace:
        from repro.obs.export import export_engine_trace
        os.makedirs(args.trace, exist_ok=True)
        path = os.path.join(args.trace, "serve_online_async.json")
        export_engine_trace(eng, path)
        print(f"  trace -> {path} (+ sibling .metrics.json)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--mode", choices=["low", "high", "volatile"],
                    default="volatile")
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--backend", choices=["sim", "async"], default="sim",
                    help="sim: simulated-clock comparison across all "
                         "strategies; async: wall-clock asyncio "
                         "front-end with streaming tokens")
    ap.add_argument("--strategy", default="cosine",
                    choices=["vanilla", "specinfer", "pipeinfer", "cosine"],
                    help="strategy for the async front-end")
    ap.add_argument("--no-stream", dest="stream", action="store_false",
                    help="suppress per-request completion lines in the "
                         "sim comparison")
    ap.add_argument("--trace", type=str, nargs="?", const="traces",
                    default=None, metavar="DIR",
                    help="export the cosine run's Perfetto trace + "
                         "metrics JSON into DIR (default ./traces)")
    args = ap.parse_args()

    from common import build_fixture
    from benchmarks.online_serving import make_arrivals

    print("== loading fixture (trains + caches on first run) ==")
    fx = build_fixture(verbose=True)

    arrivals = make_arrivals(args.mode, args.requests, seed=5)
    prompts = fx.corpus.prompts(args.requests, 16, seed=13)

    if args.backend == "async":
        asyncio.run(run_async(fx, args, arrivals, prompts))
    else:
        run_sync(fx, args, arrivals, prompts)


if __name__ == "__main__":
    main()
