"""End-to-end online serving driver: a CoSine deployment handling a
Poisson request stream across all five domains, with continuous batching,
adaptive routing, token fusion, and the Alg. 2 scheduler — then the same
stream through each baseline for comparison.

  PYTHONPATH=src python examples/serve_online.py [--requests 12] [--mode volatile]
"""
import argparse
import sys

import numpy as np

sys.path.insert(0, "benchmarks")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--mode", choices=["low", "high", "volatile"],
                    default="volatile")
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    from common import build_fixture
    from benchmarks.online_serving import make_arrivals

    print("== loading fixture (trains + caches on first run) ==")
    fx = build_fixture(verbose=True)

    arrivals = make_arrivals(args.mode, args.requests, seed=5)
    prompts = fx.corpus.prompts(args.requests, 16, seed=13)

    print(f"== {args.requests} requests, {args.mode} arrivals ==")
    header = f"{'strategy':<10} {'ms/token':>9} {'p95':>8} {'tok/s':>8} " \
             f"{'acc/iter':>9}"
    print(header)
    for strategy in ("ar", "vanilla", "specinfer", "pipeinfer", "cosine"):
        eng = fx.engine(strategy)
        for (p, dom), t in zip(prompts, arrivals):
            eng.submit(p, max_new_tokens=args.max_new, domain=dom,
                       arrival_ms=float(t))
        stats = eng.run()
        lat = [(r.finish_ms - r.arrival_ms) / max(len(r.generated), 1)
               for r in eng.pool.completed]
        print(f"{strategy:<10} {np.mean(lat):>9.1f} "
              f"{np.percentile(lat, 95):>8.1f} "
              f"{stats.throughput_tps:>8.1f} {stats.mean_acceptance:>9.2f}")

    print("\nper-domain routing learned by CoSine (request 0's M vector):")


if __name__ == "__main__":
    main()
