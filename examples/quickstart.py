"""Quickstart: train a tiny target + two domain drafters on the synthetic
corpus, then serve a few requests with CoSine and print the speedup vs
plain autoregressive decoding — all on CPU in ~2 minutes.

  PYTHONPATH=src python examples/quickstart.py
"""

from repro.config import CoSineConfig
from repro.configs.drafters import tiny_drafter, tiny_target
from repro.data.synthetic import DOMAINS, SyntheticCorpus
from repro.launch.train import train_model
from repro.serving.engine import SpeculativeEngine

VOCAB = 96


def main():
    corpus = SyntheticCorpus(VOCAB, seed=0, sharpness=60.0, support=6)

    print("== training target (mixture of 5 domains) ==")
    tcfg = tiny_target(VOCAB)
    tparams, tl = train_model(tcfg, corpus, None, steps=250, batch=16,
                              seq=64, log_every=100)

    print("== fine-tuning two domain drafters ==")
    dcfg = tiny_drafter(VOCAB)
    drafters = []
    for i, dom in enumerate(DOMAINS[:2]):
        dp, _ = train_model(dcfg, corpus, dom, steps=180, batch=16, seq=64,
                            seed=i + 1, log_every=100)
        drafters.append((dcfg, dp, dom))

    print("== serving 4 requests (piqa/medqa): CoSine vs AR ==")
    prompts = [pd for pd in corpus.prompts(20, 16, seed=3)
               if pd[1] in DOMAINS[:2]][:4]
    results = {}
    for strategy in ("ar", "cosine"):
        cos = CoSineConfig(n_drafters=2, draft_len=5, drafters_per_request=2,
                           tree_width=2)
        eng = SpeculativeEngine((tcfg, tparams), drafters, cos,
                                strategy=strategy, max_len=512)
        for p, dom in prompts:
            eng.submit(p, max_new_tokens=32, domain=dom)
        stats = eng.run()
        results[strategy] = (stats, {tuple(r.prompt.tolist()): r.generated
                                     for r in eng.pool.completed})
        print(f"  {strategy:7s}: {stats.total_committed} tokens in "
              f"{stats.sim_ms:.0f} sim-ms "
              f"({stats.throughput_tps:.1f} tok/s, "
              f"{stats.mean_acceptance:.2f} tokens/iteration)")

    assert results["ar"][1] == results["cosine"][1], "losslessness violated!"
    sp = results["cosine"][0].throughput_tps / results["ar"][0].throughput_tps
    print(f"\nCoSine speedup over AR: {sp:.2f}x — outputs bit-identical ✓")


if __name__ == "__main__":
    main()
