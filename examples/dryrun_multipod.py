"""Multi-pod dry-run example: lower + compile one architecture on the
512-chip production mesh and print its roofline terms.

  python examples/dryrun_multipod.py --arch qwen2-moe-a2.7b --shape decode_32k
(no PYTHONPATH juggling needed; must run as its own process so the
host-device-count flag applies before jax initializes.)
"""
import argparse
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-moe-a2.7b")
    ap.add_argument("--shape", default="decode_32k")
    args = ap.parse_args()
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    for flags in ([], ["--multi-pod"]):
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", args.arch, "--shape", args.shape] + flags
        print("$", " ".join(cmd))
        subprocess.run(cmd, cwd=ROOT, env=env, check=True)


if __name__ == "__main__":
    main()
